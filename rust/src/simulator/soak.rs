//! Steady-state soak harness: drive one engine over a wall-clock horizon
//! of regenerating, time-varying traffic with BOUNDED memory.
//!
//! Everything the closed-loop drivers keep per-request or per-iteration is
//! either retired, drained, or sketched here:
//!
//! * completed/rejected requests are retired off the pool's front
//!   ([`RequestPool::retire_terminal`]) after their latency samples are
//!   harvested into streaming [`Summary`]s;
//! * iteration records are drained into an append-only [`JsonlStream`]
//!   every flush interval (or capped by the windowed retain limit when no
//!   trace is requested);
//! * TBT gaps go straight into the pool's summary at stamp time and spill
//!   to the quantile sketch past [`Summary::EXACT_CAP`].
//!
//! Between flushes an optional [`SloController`] retargets the hybrid
//! scheduler's token budget toward a target P99 TBT and the bounded
//! prefix-wait window toward the observed fill economics — the online
//! control loop of Sarathi-Serve (arXiv 2403.02310 §5), closed over the
//! drained per-window TBT distribution.
//!
//! [`RequestPool::retire_terminal`]: crate::coordinator::RequestPool::retire_terminal
//! [`Summary::EXACT_CAP`]: crate::util::Summary::EXACT_CAP

use std::path::PathBuf;

use crate::coordinator::{ControllerConfig, Engine, JsonlStream, SloController};
use crate::util::Summary;
use crate::workload::SoakWorkload;

/// Configuration for one soak run.
#[derive(Clone, Debug)]
pub struct SoakOpts {
    /// Simulated wall-clock horizon, seconds.
    pub horizon: f64,
    /// Flush interval, simulated seconds: trace drain + retirement +
    /// control tick + progress cadence.
    pub flush_every: f64,
    /// Stream per-iteration records here as JSONL (append-per-flush).
    pub jsonl: Option<PathBuf>,
    /// Print a one-line progress report at each flush.
    pub progress: bool,
    /// Online SLO control (requires a scheduler exposing the runtime
    /// actuators — others refuse and the loop becomes observe-only).
    pub controller: Option<ControllerConfig>,
    /// Backstop cap on retained iteration records (bounds memory even when
    /// no JSONL stream drains them).
    pub retain_iters: usize,
    /// Per-request TTFT SLO, seconds (goodput numerator condition).
    pub ttft_slo: Option<f64>,
    /// Per-request max-TBT SLO, seconds.
    pub tbt_slo: Option<f64>,
}

impl SoakOpts {
    pub fn new(horizon: f64, flush_every: f64) -> Self {
        assert!(horizon > 0.0, "horizon must be positive");
        assert!(flush_every > 0.0, "flush interval must be positive");
        SoakOpts {
            horizon,
            flush_every,
            jsonl: None,
            progress: false,
            controller: None,
            retain_iters: 4096,
            ttft_slo: None,
            tbt_slo: None,
        }
    }
}

/// Retained-memory counters sampled at one flush boundary — the soak
/// run's leak detector: between any two checkpoints past warm-up these
/// stay FLAT while `completed` keeps growing.
#[derive(Clone, Copy, Debug)]
pub struct SoakCheckpoint {
    /// Simulated time of the flush.
    pub at: f64,
    /// Requests completed (terminal) so far — monotonically increasing.
    pub completed: usize,
    /// Requests still held in the pool after retirement.
    pub retained_requests: usize,
    /// Iteration records still held in `Metrics` after the drain.
    pub retained_records: usize,
    /// Exact samples the pool's TBT summary still holds (frozen at
    /// [`Summary::EXACT_CAP`](crate::util::Summary::EXACT_CAP) once the
    /// distribution spills to the sketch).
    pub retained_tbt_samples: usize,
    /// Controller budget setpoint at this flush (initial budget when no
    /// controller runs).
    pub token_budget: usize,
    /// Windowed P99 TBT this flush acted on.
    pub p99_tbt: f64,
}

/// What a soak run produced. All distributions are streaming summaries —
/// memory is independent of the horizon.
#[derive(Clone, Debug, Default)]
pub struct SoakReport {
    /// Arrivals generated over the horizon.
    pub arrivals: usize,
    /// Requests that completed their full decode.
    pub completed: usize,
    /// Requests terminally rejected by open-loop admission.
    pub rejected: usize,
    /// Iterations executed.
    pub iterations: usize,
    /// Simulated time the run actually covered.
    pub elapsed: f64,
    /// TTFT over completed requests.
    pub ttft: Summary,
    /// TBT over every token gap (pool's streaming distribution).
    pub tbt: Summary,
    /// Normalized latency (end-to-end per output token).
    pub normalized: Summary,
    /// Requests meeting every configured SLO / requests that completed.
    pub goodput_pass: usize,
    pub goodput_total: usize,
    /// Control-loop activity (0 ticks when no controller was configured).
    pub controller_ticks: usize,
    pub controller_adjustments: usize,
    pub final_token_budget: usize,
    pub final_max_prefix_wait: usize,
    /// Per-flush retained-memory samples.
    pub checkpoints: Vec<SoakCheckpoint>,
    /// Iteration records written to the JSONL stream (0 without one).
    pub jsonl_records: usize,
    /// Records evicted by the retain cap BEFORE the stream could drain
    /// them (a flush cadence too slow for the cap; the trace has a gap).
    pub jsonl_dropped: usize,
    /// Lifecycle trace events drained from the pool's ring at flush
    /// boundaries (0 when tracing is disabled).
    pub trace_events_drained: usize,
    /// Peak events the trace ring buffered between drains — the leak
    /// detector's counter for the trace buffer (0 when disabled).
    pub trace_high_water: usize,
    /// Events the ring dropped for want of capacity (0 when disabled or
    /// when `--flush-every` drains fast enough).
    pub trace_dropped: usize,
    /// The drained events themselves, in emission order — kept ONLY when
    /// the caller enabled the pool's sink (a `--trace-out` run); bounded
    /// soak runs without tracing never touch this.
    pub events: Vec<crate::coordinator::trace::TraceEvent>,
}

impl SoakReport {
    /// Fraction of completed requests meeting every configured SLO.
    pub fn goodput(&self) -> f64 {
        if self.goodput_total == 0 {
            return 0.0;
        }
        self.goodput_pass as f64 / self.goodput_total as f64
    }
}

/// Drive `engine` over `opts.horizon` simulated seconds of `workload`.
///
/// The engine arrives configured (pool may be pre-seeded, scheduler and
/// executor chosen by the caller); the harness owns the clock: it fills
/// arrivals one flush window ahead, steps the engine, demotes prefix-wait
/// wedges exactly like [`Engine::run`], and performs the drain/retire/
/// control/progress work at each flush boundary.
pub fn run_soak(
    engine: &mut Engine,
    workload: &mut SoakWorkload,
    opts: &SoakOpts,
) -> std::io::Result<SoakReport> {
    let mut report = SoakReport::default();
    let mut stream = match &opts.jsonl {
        Some(path) => Some(JsonlStream::create(path, None)?),
        None => None,
    };
    engine.pool.enable_tbt_window();
    engine.metrics.set_retain_limit(Some(opts.retain_iters.max(1)));
    // AIMD from the ceiling: start wide-open for TTFT and let violating
    // windows walk the budget down. Pushing the starting setpoints through
    // the actuators keeps the controller's view equal to the scheduler's
    // reality; a policy that refuses them leaves the loop observe-only.
    let mut controller = opts.controller.map(|cfg| {
        let ctl = SloController::new(cfg, cfg.max_budget, 4);
        engine.scheduler.set_token_budget(ctl.token_budget());
        engine.scheduler.set_max_prefix_wait(ctl.max_prefix_wait());
        ctl
    });
    let mut iters = 0usize;
    let mut next_flush = opts.flush_every.min(opts.horizon);
    let (mut seen_hits, mut seen_fallbacks) = (0usize, 0usize);
    loop {
        // generate arrivals through the coming window (plus the one
        // lookahead draw the workload holds back)
        workload.fill_until(&mut engine.pool, next_flush);
        while engine.now < next_flush {
            iters += 1;
            assert!(iters <= engine.max_iterations, "soak exceeded iteration cap");
            if !engine.step() {
                // same wedge demotion as Engine::run: a queued request
                // waiting on a dead prefix fill is not real wedging
                if let Some(id) = engine.pool.oldest_prefix_waiter() {
                    // demote to the deepest READY ancestor on the waiter's
                    // content path (0 = plain full-price miss), mirroring
                    // Engine::run's wedge demotion
                    let ready = match engine.pool.get(id).spec.prefix.as_ref() {
                        Some(pfx) if !pfx.path.is_empty() => {
                            let bs = engine.kv.block_size().max(1);
                            let cap = engine.pool.get(id).spec.prompt_len.saturating_sub(1);
                            let kb = (pfx.len.min(cap) / bs).min(pfx.path.len());
                            if kb > 0 {
                                engine.kv.lookup_path_match(&pfx.path[..kb]).ready_tokens
                            } else {
                                0
                            }
                        }
                        _ => 0,
                    };
                    engine.pool.force_prefix_fallback(id, engine.now, ready);
                    continue;
                }
                // genuinely drained: every generated arrival is served —
                // idle forward to the flush boundary for the next window
                engine.now = next_flush;
            }
        }
        // ---- flush boundary ----
        // 1. drain iteration records into the trace (before the retain cap
        //    can evict them); detect records the cap already dropped
        if let Some(s) = stream.as_mut() {
            report.jsonl_dropped = engine.metrics.first_retained().saturating_sub(s.written());
            for rec in engine.metrics.drain_retained() {
                s.append(&rec)?;
            }
            s.flush()?;
            report.jsonl_records = s.written();
        }
        // 1b. drain the lifecycle trace ring on the same cadence, so its
        //     footprint stays bounded by one window like the records
        if engine.pool.trace.is_enabled() {
            report.trace_high_water =
                report.trace_high_water.max(engine.pool.trace.high_water());
            report.trace_dropped = engine.pool.trace.dropped() as usize;
            engine.pool.trace.drain_into(&mut report.events);
            report.trace_events_drained = report.events.len();
        }
        // 2. retire terminal requests off the pool front, harvesting their
        //    latency samples into the streaming summaries
        for r in engine.pool.retire_terminal() {
            if r.rejected_at.is_some() {
                report.rejected += 1;
                continue;
            }
            report.completed += 1;
            let mut pass = true;
            if let Some(first) = r.first_token_at {
                let ttft = first - r.arrival;
                report.ttft.add(ttft);
                pass &= !opts.ttft_slo.is_some_and(|slo| ttft > slo);
            }
            if let Some(done) = r.completed_at {
                report.normalized.add((done - r.arrival) / r.spec.decode_len.max(1) as f64);
            }
            pass &= !opts.tbt_slo.is_some_and(|slo| r.max_tbt > slo);
            report.goodput_total += 1;
            if pass {
                report.goodput_pass += 1;
            }
        }
        // 3. control tick over this window's TBT gaps + prefix deltas
        let window = engine.pool.take_tbt_window();
        let (hits, fallbacks) = (engine.metrics.prefix_hits, engine.metrics.prefix_fallbacks);
        let (dh, df) = (hits - seen_hits, fallbacks - seen_fallbacks);
        (seen_hits, seen_fallbacks) = (hits, fallbacks);
        let (p99, budget) = match controller.as_mut() {
            Some(ctl) => {
                let out = ctl.tick(&window, dh, df, engine.scheduler.as_mut());
                (out.p99_tbt, out.token_budget)
            }
            None => (window.percentile(99.0), 0),
        };
        // 4. checkpoint + progress
        report.checkpoints.push(SoakCheckpoint {
            at: engine.now,
            completed: report.completed + report.rejected,
            retained_requests: engine.pool.retained_count(),
            retained_records: engine.metrics.retained_len(),
            retained_tbt_samples: engine.pool.tbt_summary().retained_samples(),
            token_budget: budget,
            p99_tbt: p99,
        });
        if opts.progress {
            println!(
                "[soak] t={:.1}s/{:.0}s completed={} active={} retained(req={} rec={} tbt={}) \
                 p99_tbt={:.4}s budget={} events={} trace_hw={}",
                engine.now,
                opts.horizon,
                report.completed,
                engine.pool.active_count(),
                engine.pool.retained_count(),
                engine.metrics.retained_len(),
                engine.pool.tbt_summary().retained_samples(),
                p99,
                budget,
                report.trace_events_drained,
                report.trace_high_water,
            );
        }
        if next_flush >= opts.horizon {
            break;
        }
        next_flush = (next_flush + opts.flush_every).min(opts.horizon);
    }
    report.arrivals = workload.generated();
    report.iterations = engine.metrics.recorded_count();
    report.elapsed = engine.now;
    report.tbt = engine.pool.tbt_summary().clone();
    if let Some(ctl) = controller.as_ref() {
        report.controller_ticks = ctl.ticks();
        report.controller_adjustments = ctl.adjustments();
        report.final_token_budget = ctl.token_budget();
        report.final_max_prefix_wait = ctl.max_prefix_wait();
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig};
    use crate::coordinator::{Engine, HybridScheduler, KvManager, RequestPool, SimExecutor};
    use crate::costmodel::CostModel;
    use crate::workload::RateCurve;

    fn engine(budget: usize) -> Engine<'static> {
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        Engine::new(
            RequestPool::new(),
            KvManager::paged(256, 32),
            Box::new(HybridScheduler::new(budget, 16, 2)),
            Box::new(SimExecutor::new(cm)),
        )
    }

    #[test]
    fn soak_covers_the_horizon_and_serves_continuously() {
        let mut e = engine(256);
        let mut w = SoakWorkload::new(3, RateCurve::steady(3.0))
            .with_lengths((64, 256), (16, 64));
        let opts = SoakOpts::new(60.0, 10.0);
        let rep = run_soak(&mut e, &mut w, &opts).unwrap();
        assert!(rep.elapsed >= 60.0);
        assert_eq!(rep.checkpoints.len(), 6);
        assert!(rep.completed > 50, "only {} completed", rep.completed);
        assert!(rep.arrivals >= rep.completed);
        assert!(rep.ttft.count() == rep.completed);
        assert!(rep.tbt.count() > 0 && rep.tbt.min() > 0.0);
        assert_eq!(rep.goodput_total, rep.completed);
        // no SLOs configured: every completion passes
        assert_eq!(rep.goodput_pass, rep.completed);
        // completions grow monotonically across checkpoints
        assert!(rep.checkpoints.windows(2).all(|c| c[0].completed <= c[1].completed));
    }

    #[test]
    fn retirement_keeps_the_pool_small() {
        let mut e = engine(256);
        let mut w = SoakWorkload::new(5, RateCurve::steady(3.0))
            .with_lengths((64, 256), (16, 64));
        let rep = run_soak(&mut e, &mut w, &SoakOpts::new(80.0, 8.0)).unwrap();
        // the pool's id space keeps counting every arrival ever pushed
        // (one draw stays pending in the workload's lookahead)...
        assert_eq!(e.pool.len(), rep.arrivals - 1);
        // ...but retained requests stay bounded by what is in flight
        for c in &rep.checkpoints {
            assert!(
                c.retained_requests < 200,
                "pool retained {} requests at t={}",
                c.retained_requests,
                c.at
            );
        }
        assert!(e.pool.base() > 0, "retirement must have advanced the base");
    }

    #[test]
    fn controller_runs_and_reports_activity() {
        let mut e = engine(512);
        let mut w = SoakWorkload::new(9, RateCurve::steady(6.0))
            .with_lengths((128, 512), (32, 128));
        let mut opts = SoakOpts::new(60.0, 6.0);
        // an unmeetable target: every window violates, so the budget MUST
        // walk down from the ceiling (this test pins the plumbing, not the
        // physics — the load-shift acceptance test exercises real targets)
        opts.controller = Some(ControllerConfig::new(1e-6, 16, 512));
        let rep = run_soak(&mut e, &mut w, &opts).unwrap();
        assert_eq!(rep.controller_ticks, rep.checkpoints.len());
        assert!(rep.controller_adjustments > 0, "the budget never moved");
        assert!(rep.final_token_budget < 512, "budget should back off");
        // checkpoints carry the setpoint trajectory
        assert!(rep.checkpoints.iter().any(|c| c.token_budget < 512));
    }
}
