//! Multi-GPU runtime simulation (§5.3's methodology):
//!
//! * [`pipeline`] — discrete-event pipeline-parallel execution with
//!   per-stage occupancy tracking and bubble accounting (PB1/PB2/PB3 of
//!   Fig. 5 all emerge from micro-batch time variance), exposed both as a
//!   run-to-completion driver and as the resumable [`PipelineRun`]
//!   stepping API.
//! * [`transfer`] — the costed KV copy stream between replicas
//!   (disaggregation's data plane): per-pair lanes that serialize their
//!   own transfers, overlap with each other and never block compute.
//! * [`router`] — cluster-level dispatch policies: round-robin,
//!   join-shortest-queue by outstanding work, and rendezvous-hash prefix
//!   affinity with a power-of-two load shed.
//! * [`soak`] — steady-state soak harness: one engine over a wall-clock
//!   horizon of regenerating time-varying traffic, with bounded-memory
//!   telemetry (retirement + trace draining + quantile sketches) and an
//!   optional online SLO control loop over the hybrid token budget.
//! * [`cluster`] — replica-level deployment: R identical tp×pp groups
//!   serving a shared workload through a routing policy under one global
//!   event clock (the Fig. 12 comparison set, now dispatch-aware), plus
//!   the disaggregated/split [`cluster::Topology`] deployment modes.

pub mod cluster;
pub mod pipeline;
pub mod router;
pub mod soak;
pub mod transfer;

pub use cluster::{ClusterResult, ClusterSim, Topology};
pub use soak::{run_soak, SoakCheckpoint, SoakOpts, SoakReport};
pub use pipeline::{PipelineResult, PipelineRun, PipelineSim, StallOutcome, TraceEvent};
pub use transfer::{CopyFabric, TransferRecord};
pub use router::{
    rendezvous_rank, rendezvous_top2, LeastOutstandingTokens, PrefixAffinity, ReplicaView,
    RoundRobin, RoutePolicy, RouterKind,
};
