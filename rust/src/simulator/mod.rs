//! Multi-GPU runtime simulation (§5.3's methodology):
//!
//! * [`pipeline`] — discrete-event pipeline-parallel execution with
//!   per-stage occupancy tracking and bubble accounting (PB1/PB2/PB3 of
//!   Fig. 5 all emerge from micro-batch time variance).
//! * [`cluster`] — replica-level deployment: R independent tp×pp groups
//!   serving a shared workload (the Fig. 12 comparison set).

pub mod cluster;
pub mod pipeline;

pub use cluster::{ClusterResult, ClusterSim};
pub use pipeline::{PipelineResult, PipelineSim, TraceEvent};
