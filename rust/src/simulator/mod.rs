//! Multi-GPU runtime simulation (§5.3's methodology):
//!
//! * [`pipeline`] — discrete-event pipeline-parallel execution with
//!   per-stage occupancy tracking and bubble accounting (PB1/PB2/PB3 of
//!   Fig. 5 all emerge from micro-batch time variance), exposed both as a
//!   run-to-completion driver and as the resumable [`PipelineRun`]
//!   stepping API.
//! * [`router`] — cluster-level dispatch policies: round-robin,
//!   join-shortest-queue by outstanding work, and rendezvous-hash prefix
//!   affinity with a power-of-two load shed.
//! * [`cluster`] — replica-level deployment: R identical tp×pp groups
//!   serving a shared workload through a routing policy under one global
//!   event clock (the Fig. 12 comparison set, now dispatch-aware).

pub mod cluster;
pub mod pipeline;
pub mod router;

pub use cluster::{ClusterResult, ClusterSim};
pub use pipeline::{PipelineResult, PipelineRun, PipelineSim, StallOutcome, TraceEvent};
pub use router::{
    rendezvous_rank, rendezvous_top2, LeastOutstandingTokens, PrefixAffinity, ReplicaView,
    RoundRobin, RoutePolicy, RouterKind,
};
