//! The costed KV copy stream between replicas — disaggregation's data
//! plane (DistServe, arXiv 2401.09670 §4.3).
//!
//! A prefill replica that finishes a prompt exports the request's KV
//! ([`KvExport`]) and hands it to a decode replica over the replica
//! interconnect (`interconnect_gbps`, an NVLink/IB-class fabric edge
//! distinct from the PCIe `host_bw_gbps` swap path). The fabric models
//! one copy lane per ordered replica pair: transfers on the SAME pair
//! serialize (a link moves one stream at a time), transfers on different
//! pairs overlap freely, and — the point of the refactor — transfers
//! never occupy compute: they are events on the cluster clock, so a
//! decode replica keeps stepping while its next request's KV is still in
//! flight, and admission simply waits for the arrival edge.
//!
//! Conservation is tracked explicitly (every export is delivered exactly
//! once or cancelled) because the handoff is the one place KV crosses an
//! ownership boundary; `tests/cluster_disagg.rs` asserts the books close.

use crate::config::Deployment;
use crate::coordinator::{KvExport, JSONL_SCHEMA_VERSION};

/// One KV handoff on the wire: request, endpoints, size and timing.
/// `start − ready_at` is queueing on the pair's lane; `finish − ready_at`
/// is the request's end-to-end `kv_transfer_time`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferRecord {
    /// Global (cluster-order) request index.
    pub request: usize,
    pub src: usize,
    pub dst: usize,
    pub kv_tokens: usize,
    /// Bytes moved per GPU (each GPU ships its own KV shard on its own
    /// link, so the per-GPU shard size is the serialization unit).
    pub bytes: f64,
    /// When the prefill finished and the export became available.
    pub ready_at: f64,
    pub start: f64,
    pub finish: f64,
}

impl TransferRecord {
    /// The request's transfer latency: lane queueing + wire time.
    pub fn kv_transfer_time(&self) -> f64 {
        self.finish - self.ready_at
    }

    /// One JSON-Lines record, tagged `"transfer"` so colocated traces
    /// (which have none) stay byte-identical to the pre-refactor schema.
    pub fn to_jsonl(&self) -> String {
        format!(
            "{{\"transfer\":{{\"request\":{},\"src\":{},\"dst\":{},\
             \"kv_tokens\":{},\"bytes\":{:.1},\"ready_at\":{:.6},\
             \"start\":{:.6},\"finish\":{:.6},\"kv_transfer_time\":{:.6},\
             \"schema_version\":{}}}}}",
            self.request,
            self.src,
            self.dst,
            self.kv_tokens,
            self.bytes,
            self.ready_at,
            self.start,
            self.finish,
            self.kv_transfer_time(),
            JSONL_SCHEMA_VERSION,
        )
    }
}

/// The cluster's copy fabric: one lane per ordered replica pair, each
/// serializing its own transfers, all overlapping with compute and with
/// each other.
#[derive(Clone, Debug)]
pub struct CopyFabric {
    replicas: usize,
    /// Interconnect bandwidth, bytes/s.
    bw: f64,
    /// KV bytes per token PER GPU (each GPU ships its own shard).
    bytes_per_token: f64,
    /// Earliest-free time per (src, dst) lane.
    free: Vec<f64>,
    /// Every transfer begun, in begin order.
    pub records: Vec<TransferRecord>,
    exported: usize,
    delivered: usize,
    cancelled: usize,
}

impl CopyFabric {
    pub fn new(replicas: usize, interconnect_gbps: f64, bytes_per_token: f64) -> Self {
        assert!(interconnect_gbps > 0.0, "interconnect bandwidth must be positive");
        CopyFabric {
            replicas,
            bw: interconnect_gbps * 1e9,
            bytes_per_token,
            free: vec![0.0; replicas * replicas],
            records: Vec::new(),
            exported: 0,
            delivered: 0,
            cancelled: 0,
        }
    }

    /// Fabric for a deployment: the GPU's `interconnect_gbps` and the
    /// model's per-GPU KV shard size.
    pub fn for_deployment(dep: &Deployment, replicas: usize) -> Self {
        Self::new(replicas, dep.gpu.interconnect_gbps, dep.kv_bytes_per_token_per_gpu())
    }

    /// Wire time for `kv_tokens` of KV, ignoring lane queueing.
    pub fn transfer_time(&self, kv_tokens: usize) -> f64 {
        kv_tokens as f64 * self.bytes_per_token / self.bw
    }

    /// Start a handoff: the export becomes available at `ready_at`, waits
    /// for the (src → dst) lane if it is mid-copy, then moves at wire
    /// speed. Returns the arrival time at `dst` — the earliest instant
    /// decode admission may see the request. Compute on both replicas is
    /// untouched; only the lane's clock advances.
    pub fn begin(
        &mut self,
        request: usize,
        src: usize,
        dst: usize,
        export: &KvExport,
        ready_at: f64,
    ) -> f64 {
        assert!(src < self.replicas && dst < self.replicas, "transfer endpoints out of range");
        assert!(src != dst, "intra-replica handoff moves no KV (skip the fabric)");
        let bytes = export.kv_tokens as f64 * self.bytes_per_token;
        let lane = src * self.replicas + dst;
        let start = self.free[lane].max(ready_at);
        let finish = start + bytes / self.bw;
        self.free[lane] = finish;
        self.exported += 1;
        self.records.push(TransferRecord {
            request,
            src,
            dst,
            kv_tokens: export.kv_tokens,
            bytes,
            ready_at,
            start,
            finish,
        });
        finish
    }

    /// The destination materialized the export into its own pool.
    pub fn deliver(&mut self, request: usize) {
        debug_assert!(
            self.records.iter().any(|r| r.request == request),
            "delivering a transfer that never began"
        );
        self.delivered += 1;
    }

    /// The export was abandoned before materializing (e.g. its request
    /// would never decode). Kept for the conservation books — the driver
    /// only begins transfers for prompts that WILL decode, so this stays
    /// unused on the happy path.
    pub fn cancel(&mut self, request: usize) {
        debug_assert!(
            self.records.iter().any(|r| r.request == request),
            "cancelling a transfer that never began"
        );
        self.cancelled += 1;
    }

    /// Conservation: every export delivered exactly once or cancelled.
    pub fn is_conserved(&self) -> bool {
        self.exported == self.delivered + self.cancelled
    }

    pub fn exported(&self) -> usize {
        self.exported
    }

    pub fn delivered(&self) -> usize {
        self.delivered
    }

    pub fn cancelled(&self) -> usize {
        self.cancelled
    }

    /// Total lane-busy time (wire time summed over all transfers — lane
    /// queueing excluded, so this is time the fabric actually moved bytes).
    pub fn busy_time(&self) -> f64 {
        self.records.iter().map(|r| r.finish - r.start).sum()
    }

    /// Total bytes moved.
    pub fn total_bytes(&self) -> f64 {
        self.records.iter().map(|r| r.bytes).sum()
    }

    /// Mean concurrently-busy lanes over `makespan` (can exceed 1.0 when
    /// disjoint pairs overlap — that overlap is the refactor's win).
    pub fn utilization(&self, makespan: f64) -> f64 {
        if makespan <= 0.0 {
            0.0
        } else {
            self.busy_time() / makespan
        }
    }

    /// Trace summary line (written once after the per-transfer records).
    pub fn summary_jsonl(&self, makespan: f64) -> String {
        format!(
            "{{\"transfer_stream\":{{\"transfers\":{},\"bytes\":{:.1},\
             \"busy\":{:.6},\"utilization\":{:.6},\"schema_version\":{}}}}}",
            self.records.len(),
            self.total_bytes(),
            self.busy_time(),
            self.utilization(makespan),
            JSONL_SCHEMA_VERSION,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> CopyFabric {
        // 10 GB/s, 1 MB per token → 1e-4 s per token: easy arithmetic
        CopyFabric::new(4, 10.0, 1.0e6)
    }

    #[test]
    fn same_pair_serializes_different_pairs_overlap() {
        let mut f = fabric();
        let ex = KvExport { kv_tokens: 1000, blocks: 10 };
        // 1000 tokens × 1e6 B / 1e10 B/s = 0.1 s on the wire
        let t1 = f.begin(0, 0, 2, &ex, 0.0);
        assert!((t1 - 0.1).abs() < 1e-12);
        // same lane, ready mid-copy: queues behind the first
        let t2 = f.begin(1, 0, 2, &ex, 0.05);
        assert!((t2 - 0.2).abs() < 1e-12);
        assert!((f.records[1].start - 0.1).abs() < 1e-12, "lane busy until 0.1");
        // different pair: overlaps freely
        let t3 = f.begin(2, 1, 3, &ex, 0.05);
        assert!((t3 - 0.15).abs() < 1e-12);
        // per-pair busy intervals never overlap
        for w in f.records.windows(2) {
            if (w[0].src, w[0].dst) == (w[1].src, w[1].dst) {
                assert!(w[1].start >= w[0].finish);
            }
        }
        assert!((f.busy_time() - 0.3).abs() < 1e-12);
        assert!((f.utilization(0.2) - 1.5).abs() < 1e-12, "overlapping pairs exceed 1");
    }

    #[test]
    fn conservation_books_close_only_when_every_export_lands() {
        let mut f = fabric();
        let ex = KvExport { kv_tokens: 64, blocks: 2 };
        f.begin(0, 0, 1, &ex, 0.0);
        f.begin(1, 0, 1, &ex, 0.0);
        assert!(!f.is_conserved(), "in-flight exports are not conserved yet");
        f.deliver(0);
        f.cancel(1);
        assert!(f.is_conserved());
        assert_eq!((f.exported(), f.delivered(), f.cancelled()), (2, 1, 1));
    }

    #[test]
    fn record_jsonl_has_the_kv_transfer_time_field() {
        let mut f = fabric();
        let ex = KvExport { kv_tokens: 1000, blocks: 10 };
        f.begin(7, 0, 3, &ex, 1.0);
        let line = f.records[0].to_jsonl();
        assert!(line.starts_with("{\"transfer\":{\"request\":7,\"src\":0,\"dst\":3,"));
        assert!(line.contains("\"kv_transfer_time\":0.100000"));
        assert!(line.ends_with("}}"));
        let summary = f.summary_jsonl(1.0);
        assert!(summary.starts_with("{\"transfer_stream\":{\"transfers\":1,"));
        assert!(summary.contains("\"busy\":0.100000"));
    }

    #[test]
    #[should_panic(expected = "intra-replica")]
    fn intra_replica_transfers_are_rejected() {
        let mut f = fabric();
        f.begin(0, 1, 1, &KvExport { kv_tokens: 1, blocks: 1 }, 0.0);
    }

    #[test]
    fn deployment_fabric_prices_a_known_shard() {
        use crate::config::{GpuConfig, ModelConfig};
        let dep = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
        let f = CopyFabric::for_deployment(&dep, 2);
        // llama13b: 819200 B/token over 50 GB/s
        let expect = 819200.0 / 50.0e9;
        assert!((f.transfer_time(1) - expect).abs() < 1e-18);
    }
}
