//! Discrete-event pipeline-parallel simulator with bubble accounting.
//!
//! Model (§2.3, §3.2): one replica = `pp` stages, each owning
//! `layers/pp` layers (tensor-parallel `tp`-wide inside). Iteration-level
//! scheduling keeps `pp` independent micro-batch *streams* in flight — a
//! stream's next iteration can only be scheduled after its previous
//! micro-batch leaves the last stage (the autoregressive dependency), which
//! is exactly why Orca needs ≥ pp concurrent request groups to fill the
//! pipeline (Fig. 5 runs two groups, A/B and C/D).
//!
//! A **bubble** is any idle gap on a stage between two consecutive
//! micro-batches while work is still pending — caused by micro-batch
//! execution-time variance (PB1: consecutive prefills of different length;
//! PB2: prefill followed by decode; PB3: decode KV-length variance). The
//! simulator attributes each gap to the requests of the micro-batch whose
//! late arrival caused it, giving the paper's per-request bubble metric
//! (Fig. 12a).
//!
//! KV and the state transition are SHARED with the engine:
//!
//! * All `pp` streams draw from **one** [`KvManager`] per replica — the
//!   pool a real stage holds. (The seed gave each stream its own
//!   `KvManager::new(slots)`, overcommitting replica KV memory by pp×.)
//!   Admission runs per stream through the scheduler's own gate plus an
//!   optional per-stream sequence cap; when a stream's decode growth runs
//!   dry it preempts the most-recently-arrived request of ANY stream.
//! * Each micro-batch advances through [`StepApplier`] — the same
//!   transition `Engine` runs, so progress counters, token-time stamping
//!   (TTFT/TBT are now correct for pipeline runs), completion release,
//!   token-granular growth and costed preemption can never drift from the
//!   engine again. Swap-in/-out transfer time shows up as stage idle time,
//!   i.e. as pipeline bubbles — exactly DistServe's point about pricing KV
//!   movement.
//!
//! Event model: a stream alternates `Schedule` (admission + composition +
//! stage walk, at its ready time) and `Apply` (state transition, at the
//! micro-batch's exit from the last stage). Events are processed in global
//! time order, so one stream's completions/preemptions are visible to
//! another stream's admission at the correct simulated time. A stream with
//! live requests but nothing schedulable *stalls* until some other
//! stream's `Apply` frees blocks; if every unfinished stream is stalled at
//! once the run panics loudly ("pipeline wedged") instead of silently
//! dropping requests into NaN completions, mirroring `Engine::run`.
//!
//! The whole event loop lives in [`PipelineRun`], a *resumable* stepping
//! API: requests are `push`ed (round-robin across streams), events are
//! processed one at a time via `step`, and stall resolution (cache-wait
//! demotion vs the wedged panic) is an explicit caller decision. This is
//! what lets [`crate::simulator::ClusterSim`] interleave R replica runs
//! under one global clock and dispatch arrivals by a routing policy;
//! [`PipelineSim::run_shared`] is the single-replica driver over the same
//! machinery.

use crate::coordinator::{
    Batch, IterationRecord, KvManager, LatencyReport, Metrics, RequestPool, ResidencyDigest,
    Scheduler, StageKv, StepApplier, SwapCost,
};
// aliased: `trace::TraceEvent` (lifecycle events) is a different type from
// this module's Fig.-5 schedule `TraceEvent`
use crate::coordinator::trace as ctrace;
use crate::costmodel::BatchShape;
use crate::profiler::Profiler;
use crate::util::Summary;
use crate::workload::RequestSpec;

/// One stage-execution event, for schedule traces (Fig. 5).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub micro_batch: usize,
    pub stream: usize,
    pub stage: usize,
    pub start: f64,
    pub end: f64,
    /// Idle gap on this stage immediately before this event.
    pub gap: f64,
    /// Composition summary: (prefill tokens, decode tokens).
    pub tokens: (usize, usize),
}

/// Outcome of one pipeline run.
#[derive(Clone, Debug, Default)]
pub struct PipelineResult {
    /// Total simulated time until the last request completes.
    pub makespan: f64,
    /// Completion time per request (absolute, seconds). NaN only for
    /// requests rejected as infeasible (open-loop admission policy).
    pub completions: Vec<f64>,
    /// Per-request accumulated bubble time (Fig. 12a's metric).
    pub bubble_per_request: Vec<f64>,
    /// Total stage-idle (bubble) time across all stages.
    pub total_bubble: f64,
    /// Total busy time across all stages (for utilization).
    pub total_busy: f64,
    /// Number of micro-batches executed.
    pub micro_batches: usize,
    /// Per-request TTFT/TBT/normalized latency — correct because token
    /// stamping goes through the engine-shared [`StepApplier`].
    pub latency: LatencyReport,
    /// First-token time per request (absolute; NaN for requests that
    /// never produced one, e.g. rejected). Indexed like `completions`.
    pub first_tokens: Vec<f64>,
    /// Per-request fallback flag: true when the request's cache-aware
    /// prefix wait degraded to a full-price miss (bounded-wait expiry or
    /// wedge demotion) — the liveness suite compares these victims' TTFT
    /// against a no-sharing run.
    pub prefix_fallback: Vec<bool>,
    /// Per-request maximum time-between-tokens gap (0.0 with fewer than
    /// two stamped tokens) — the per-request TBT that goodput SLOs check.
    pub max_tbt: Vec<f64>,
    /// Preemption transfer time routed onto the overlapped copy stream
    /// instead of serializing compute — 0.0 unless the run opted in via
    /// [`PipelineRun::set_overlap_swaps`].
    pub copy_busy: f64,
    /// Per-micro-batch records (KV occupancy, preemptions, swap time) —
    /// `metrics.write_jsonl` gives the pipeline run a trace like the
    /// engine's.
    pub metrics: Metrics,
    /// Per-stage schedule trace (recorded when `PipelineSim::trace` is on).
    pub trace: Vec<TraceEvent>,
    /// Canonically-merged lifecycle event stream from every per-stream
    /// sink — empty unless [`PipelineRun::enable_trace`] was called.
    /// Request ids inside events are stream-pool-local; the event's
    /// `(replica, lane)` identifies the pool.
    pub events: Vec<ctrace::TraceEvent>,
    /// Per-request TTFT/e2e latency decomposition (always computed at
    /// [`PipelineRun::finish`]; `request` remapped to the run-local
    /// push-order index). Imported decode-side requests are excluded —
    /// their TTFT belongs to the prefill replica; the cluster driver
    /// stitches the disaggregated decomposition itself.
    pub breakdowns: Vec<ctrace::LatencyBreakdown>,
    /// Lazily-computed sort of `completions` — an internal memo so curve
    /// queries stop cloning + sorting per call. Public only so external
    /// struct literals with `..Default::default()` keep compiling; leave
    /// it untouched when building results by hand.
    pub sorted_completions: std::sync::OnceLock<Vec<f64>>,
}

impl PipelineResult {
    pub fn bubble_summary(&self) -> Summary {
        let mut s = Summary::new();
        for &b in &self.bubble_per_request {
            s.add(b);
        }
        s
    }

    /// Sorted completion curve: (i+1 requests done, time) — Fig. 12b.
    /// Sorted once per result (NaN rejections last under `total_cmp`).
    pub fn completion_curve(&self) -> Vec<(usize, f64)> {
        let sorted = self.sorted_completions.get_or_init(|| {
            let mut c = self.completions.clone();
            c.sort_by(f64::total_cmp);
            c
        });
        sorted.iter().enumerate().map(|(i, &t)| (i + 1, t)).collect()
    }

    pub fn utilization(&self) -> f64 {
        if self.total_busy + self.total_bubble == 0.0 {
            0.0
        } else {
            self.total_busy / (self.total_busy + self.total_bubble)
        }
    }
}

/// What a stream does next. One pending event per stream; processed in
/// global (time, Apply-before-Schedule, stream-index) order.
enum Event {
    /// Ready to admit + compose its next micro-batch.
    Schedule(f64),
    /// Nothing schedulable until the stream's next KNOWN arrival — same
    /// processing as `Schedule`, but a later `push` may legitimately pull
    /// it earlier (a busy-until `Schedule` after an `Apply` may not).
    Idle(f64),
    /// A micro-batch in flight: advance state when it exits the last stage.
    Apply {
        at: f64,
        batch: Batch,
        shape: BatchShape,
        started_at: f64,
        stage_time: f64,
        swap_in: f64,
        /// Schedule-order micro-batch id, carried so the apply-side
        /// `ChunkScheduled` events agree with the schedule-side
        /// `BatchSpan` ids even when applies reorder.
        batch_id: u64,
        prefix_hits: usize,
        prefix_partial_hits: usize,
        prefix_partial_hit_tokens: usize,
        prefix_fallbacks: usize,
        prefix_wait_iters: usize,
    },
    /// Live requests but nothing schedulable; woken by any other stream's
    /// Apply (which may free blocks) or by a routed arrival. All streams
    /// stalled with no waiter to demote = wedged.
    Stalled,
    /// Every request terminal.
    Done,
}

/// How a fully-stalled run was resolved by [`PipelineRun::resolve_stall`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallOutcome {
    /// No stream is stalled: the run is simply out of events (done, or
    /// waiting for the caller to push more arrivals).
    Idle,
    /// A cache-wait cycle was broken: the oldest prefix waiter was demoted
    /// to a full-price fallback and every stalled stream was woken.
    Demoted,
    /// Every unfinished stream is stalled with NO waiter to demote — the
    /// caller should fail loudly via [`PipelineRun::panic_wedged`].
    Wedged,
}

/// Pipeline-parallel simulator for one replica.
pub struct PipelineSim {
    pub profiler: Profiler,
    pub pp: usize,
    /// Record a full per-stage schedule trace (Fig. 5 demonstrations).
    pub trace: bool,
    /// The engine-shared state transition; carries the preemption
    /// [`SwapCost`] (default: the seed's free swaps).
    pub applier: StepApplier,
    /// Hidden size × bytes for activation transfer between stages.
    act_bytes_per_token: f64,
    p2p_bw: f64,
}

impl PipelineSim {
    /// `profiler` must be built from a per-STAGE cost model
    /// (`CostModel::for_deployment` divides layers by pp).
    pub fn new(profiler: Profiler, pp: usize) -> Self {
        let cm = profiler.cost_model();
        let act_bytes_per_token = (cm.model.hidden * cm.model.bytes_per_param) as f64;
        let p2p_bw = cm.gpu.p2p_bw_gbps * 1e9;
        PipelineSim {
            profiler,
            pp,
            trace: false,
            applier: StepApplier::new(),
            act_bytes_per_token,
            p2p_bw,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// Price the preemption path (seed default: free swaps).
    pub fn with_swap_cost(mut self, swap: SwapCost) -> Self {
        self.applier = StepApplier::with_cost(swap);
        self
    }

    fn p2p_time(&self, tokens: usize) -> f64 {
        if self.pp == 1 {
            return 0.0;
        }
        tokens as f64 * self.act_bytes_per_token / self.p2p_bw
    }

    /// Run the workload to completion over the seed-compatible degenerate
    /// layout: a shared pool of `pp × slots_per_stream` whole-request
    /// slots with each stream's admission capped at `slots_per_stream` —
    /// exactly the per-stream capacity the seed granted, now drawn from
    /// one accounted pool. `make_sched` builds one scheduler per stream.
    pub fn run<'a, F>(
        &self,
        specs: &[RequestSpec],
        slots_per_stream: usize,
        make_sched: F,
    ) -> PipelineResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        let slots = self.pp.max(1) * slots_per_stream;
        self.run_shared(specs, KvManager::new(slots), Some(slots_per_stream), make_sched)
    }

    /// Run the workload over an explicit shared per-replica [`KvManager`]
    /// (paged or degenerate). `per_stream_cap` additionally bounds each
    /// stream's admitted sequences (on top of the scheduler's own gate);
    /// `None` bounds admission by memory alone.
    pub fn run_shared<'a, F>(
        &self,
        specs: &[RequestSpec],
        kv: KvManager,
        per_stream_cap: Option<usize>,
        make_sched: F,
    ) -> PipelineResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        self.run_shared_traced(specs, kv, per_stream_cap, make_sched, None)
    }

    /// [`run_shared`](Self::run_shared) with the lifecycle event bus on:
    /// `trace_cap` sizes each stream's sink (replica id 0) and the
    /// merged stream lands in [`PipelineResult::events`]. `None` keeps
    /// every sink disabled — identical to `run_shared`.
    pub fn run_shared_traced<'a, F>(
        &self,
        specs: &[RequestSpec],
        kv: KvManager,
        per_stream_cap: Option<usize>,
        mut make_sched: F,
        trace_cap: Option<usize>,
    ) -> PipelineResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        let mut run = PipelineRun::new(self, kv, per_stream_cap, &mut make_sched);
        if let Some(cap) = trace_cap {
            run.enable_trace(0, cap);
        }
        for spec in specs {
            run.push(spec.clone());
        }
        loop {
            if run.step() {
                continue;
            }
            match run.resolve_stall() {
                StallOutcome::Demoted => continue,
                StallOutcome::Wedged => run.panic_wedged(),
                StallOutcome::Idle => break,
            }
        }
        run.finish()
    }
}

/// One replica's in-flight pipeline execution, advanced one event at a
/// time. Owns the per-stream pools/schedulers, the shared KV pool and the
/// accumulating [`PipelineResult`]; the driver (single-replica
/// [`PipelineSim::run_shared`] or the cluster's routed dispatch) decides
/// when to step, when to push arrivals, and how to resolve stalls.
pub struct PipelineRun<'a, 'b> {
    sim: &'b PipelineSim,
    n_streams: usize,
    per_stream_cap: Option<usize>,
    pools: Vec<RequestPool>,
    // `Send` so a cluster worker thread may own the run between dispatch
    // barriers (every concrete scheduler is plain data)
    scheds: Vec<Box<dyn Scheduler + Send + 'a>>,
    /// Per-stage KV ownership: one canonical pool mirrored across the
    /// replica's `pp` stages (see [`StageKv`]) — allocation decisions are
    /// exact for every stage, byte accounting splits by layer share.
    kv: StageKv,
    events: Vec<Event>,
    /// Swap-in time charged by admission while no batch ran yet; carried
    /// to the stream's next micro-batch.
    pending_swap_in: Vec<f64>,
    /// Prefix-cache hits observed at admission, attached to the stream's
    /// next micro-batch record (same carry as swap-in).
    pending_prefix_hits: Vec<usize>,
    /// Radix partial hits (ancestor-depth matches) and the KV tokens they
    /// skipped, same carry.
    pending_prefix_partial_hits: Vec<usize>,
    pending_prefix_partial_hit_tokens: Vec<usize>,
    /// Bounded-wait fallbacks and wait ticks, same carry.
    pending_prefix_fallbacks: Vec<usize>,
    pending_wait_ticks: Vec<usize>,
    /// Latest simulated time any event was processed at — the wake time
    /// for wedge demotion and the floor for pushed arrivals.
    clock: f64,
    stage_free: Vec<f64>,
    stage_used: Vec<bool>,
    /// Per stream: stream-local request id → run-local result index.
    global_ids: Vec<Vec<usize>>,
    /// Round-robin cursor for `push`'s stream assignment.
    next_stream: usize,
    /// Reused (stream, request) scratch for the per-apply in-flight scan —
    /// rebuilding it per event was the step path's hottest allocation.
    scratch_in_flight: Vec<(usize, usize)>,
    /// Completions since the last [`take_finished`](Self::take_finished)
    /// drain, as (run-local index, completion time) — the disaggregation
    /// driver's handoff edge (a finished prefill becomes a transfer).
    finish_events: Vec<(usize, f64)>,
    /// Route preemption swap transfers onto the overlapped copy stream
    /// (accumulated in `swap_busy`) instead of serializing compute around
    /// the iteration — disaggregated topologies own a copy stream anyway,
    /// so swaps ride it. Default false: every existing path is
    /// byte-identical.
    overlap_swaps: bool,
    swap_busy: f64,
    result: PipelineResult,
}

impl<'a, 'b> PipelineRun<'a, 'b> {
    /// Fresh run over `kv`, one scheduler per stream from `make_sched` —
    /// the usual one-stream-per-pipeline-stage layout.
    pub fn new<F>(
        sim: &'b PipelineSim,
        kv: KvManager,
        per_stream_cap: Option<usize>,
        make_sched: &mut F,
    ) -> Self
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        let n_streams = sim.pp.max(1);
        Self::with_streams(sim, kv, per_stream_cap, make_sched, n_streams)
    }

    /// [`new`](Self::new) with an explicit stream count. More streams than
    /// stages time-share the stages' compute (every stream's micro-batch
    /// still walks all `pp` stages) — the RAPID-Serve intra-replica split
    /// runs a prefill lane and a decode lane as two streams over one
    /// stage's compute. `make_sched` is called once per stream, stream 0
    /// first, so a lane-partitioned factory can hand each lane its own
    /// budget.
    pub fn with_streams<F>(
        sim: &'b PipelineSim,
        kv: KvManager,
        per_stream_cap: Option<usize>,
        make_sched: &mut F,
        n_streams: usize,
    ) -> Self
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        assert!(n_streams >= 1, "a replica runs at least one stream");
        PipelineRun {
            sim,
            n_streams,
            per_stream_cap,
            pools: (0..n_streams).map(|_| RequestPool::new()).collect(),
            scheds: (0..n_streams).map(|_| make_sched()).collect(),
            kv: StageKv::mirrored(kv, sim.pp.max(1)),
            events: (0..n_streams).map(|_| Event::Schedule(0.0)).collect(),
            pending_swap_in: vec![0.0; n_streams],
            pending_prefix_hits: vec![0; n_streams],
            pending_prefix_partial_hits: vec![0; n_streams],
            pending_prefix_partial_hit_tokens: vec![0; n_streams],
            pending_prefix_fallbacks: vec![0; n_streams],
            pending_wait_ticks: vec![0; n_streams],
            clock: 0.0,
            stage_free: vec![0.0; sim.pp],
            stage_used: vec![false; sim.pp],
            global_ids: vec![Vec::new(); n_streams],
            next_stream: 0,
            scratch_in_flight: Vec::new(),
            finish_events: Vec::new(),
            overlap_swaps: false,
            swap_busy: 0.0,
            result: PipelineResult::default(),
        }
    }

    /// Route preemption swap transfers onto the overlapped copy stream:
    /// swap time accumulates in [`copy_busy`](Self::copy_busy) instead of
    /// delaying the stream's next schedule — KV movement becomes an event
    /// on the transfer clock, not a compute serialization. Off by default
    /// (existing paths byte-identical).
    pub fn set_overlap_swaps(&mut self, on: bool) {
        self.overlap_swaps = on;
    }

    /// Swap transfer time accumulated on the copy stream so far.
    pub fn copy_busy(&self) -> f64 {
        self.swap_busy
    }

    /// Turn on lifecycle tracing for every stream pool: one pre-sized
    /// sink per stream, identified as `(replica, stream)`. Call before
    /// the first push so arrival events are captured. No-op cost
    /// elsewhere: pools default to a disabled sink.
    pub fn enable_trace(&mut self, replica: u32, cap: usize) {
        for (si, pool) in self.pools.iter_mut().enumerate() {
            pool.trace = ctrace::TraceSink::enabled(cap);
            pool.trace.set_identity(replica, si as u32);
        }
    }

    /// Aggregate (high-water, dropped) across the per-stream sinks —
    /// the soak/cluster drivers report buffer pressure from these.
    pub fn trace_pressure(&self) -> (usize, u64) {
        let hw = self.pools.iter().map(|p| p.trace.high_water()).max().unwrap_or(0);
        let dropped = self.pools.iter().map(|p| p.trace.dropped()).sum();
        (hw, dropped)
    }

    /// Add a request to the run (streams are filled round-robin in push
    /// order — the same `local % pp` partition the batch driver used).
    /// Returns the run-local result index. Waking is only ever *earlier*:
    /// a Done/Stalled stream re-schedules at the arrival, an idle-until
    /// stream's wake moves up; a busy stream's pending events stand.
    pub fn push(&mut self, spec: RequestSpec) -> usize {
        let si = self.next_stream;
        self.next_stream = (self.next_stream + 1) % self.n_streams;
        self.push_to(si, spec)
    }

    /// [`push`](Self::push) onto an explicit stream — topology drivers
    /// pin arrivals to a lane (prefill vs decode) instead of round-robin.
    pub fn push_to(&mut self, si: usize, spec: RequestSpec) -> usize {
        let local = self.result.completions.len();
        let arrival = spec.arrival;
        self.pools[si].push(spec);
        self.global_ids[si].push(local);
        self.result.completions.push(f64::NAN);
        self.result.bubble_per_request.push(0.0);
        self.result.first_tokens.push(f64::NAN);
        self.result.prefix_fallback.push(false);
        self.result.max_tbt.push(0.0);
        let at = arrival.max(self.clock);
        let wake_at = match &self.events[si] {
            Event::Done | Event::Stalled => Some(at),
            Event::Idle(t) if at < *t => Some(at),
            _ => None,
        };
        if let Some(w) = wake_at {
            self.events[si] = Event::Idle(w);
        }
        local
    }

    /// Push a request whose prompt KV just arrived over the interconnect
    /// (disaggregation handoff): `spec.arrival` must be the transfer's
    /// finish time — admission cannot see the request before its KV
    /// lands — and `first_token_at` the prefill side's first-token stamp.
    /// The request enters decode-ready (prompt prefilled, first token
    /// produced elsewhere) with [`Request::imported`] set, so its first
    /// admission skips the host-link swap charge; its next token's TBT gap
    /// is measured from `first_token_at`, which makes the transfer +
    /// decode-queueing latency visible in `max_tbt` exactly where an SLO
    /// would feel it.
    ///
    /// [`Request::imported`]: crate::coordinator::Request::imported
    pub fn push_imported(&mut self, si: usize, spec: RequestSpec, first_token_at: f64) -> usize {
        debug_assert!(spec.decode_len > 1, "a handoff without decode work is pointless");
        debug_assert!(first_token_at <= spec.arrival, "first token precedes the transfer");
        let prompt_len = spec.prompt_len;
        let local = self.push_to(si, spec);
        let pool = &mut self.pools[si];
        let id = pool.len() - 1;
        {
            let r = pool.get_mut(id);
            r.prefilled = prompt_len;
            r.decoded = 1;
            r.imported = true;
        }
        pool.stamp_token(id, first_token_at);
        local
    }

    /// Drain completions recorded since the last call, as (run-local
    /// index, completion time) in completion order — the handoff driver
    /// turns a prefill replica's finished prompts into transfers.
    pub fn take_finished(&mut self) -> Vec<(usize, f64)> {
        std::mem::take(&mut self.finish_events)
    }

    /// Earliest pending (timed) event across streams, if any. `None` means
    /// every stream is Done or Stalled — the caller either pushes more
    /// arrivals or resolves the stall.
    pub fn next_event_time(&self) -> Option<f64> {
        let mut min_t: Option<f64> = None;
        for ev in &self.events {
            let t = match ev {
                Event::Schedule(t) | Event::Idle(t) => *t,
                Event::Apply { at, .. } => *at,
                Event::Stalled | Event::Done => continue,
            };
            min_t = Some(match min_t {
                None => t,
                Some(m) => m.min(t),
            });
        }
        min_t
    }

    /// Process every pending event strictly before `horizon`, in this
    /// replica's usual event order. The strict `<` is the cluster
    /// dispatcher's arrival-beats-event tie-break: an event AT the horizon
    /// instant belongs to the round after the dispatch it ties with, so a
    /// parallel drain up to each arrival stays bitwise identical to the
    /// serial loop. NaN event times fail loudly here, mirroring the
    /// serial dispatcher's heap-key assertion.
    pub fn advance_until(&mut self, horizon: f64) {
        while let Some(t) = self.next_event_time() {
            assert!(!t.is_nan(), "replica produced a NaN event time");
            if t < horizon {
                self.step();
            } else {
                break;
            }
        }
    }

    /// True when every request ever pushed reached a terminal state.
    pub fn is_complete(&self) -> bool {
        self.pools.iter().all(|p| p.all_complete())
    }

    /// Compact digest of this replica's READY resident prefix subtrees —
    /// the cluster dispatcher refreshes it at routing barriers so the
    /// digest-aware affinity policy scores ACTUAL residency instead of
    /// guessing from dispatch history.
    pub fn residency_digest(&self) -> ResidencyDigest {
        self.kv.pool().residency_digest()
    }

    /// Cache-aware outstanding work: prefill + decode tokens this replica
    /// still has to COMPUTE for its non-terminal requests. Queued
    /// prefix-tagged requests are discounted by their template's resident
    /// coverage (they will skip it at admission — `lookup_prefix` counts a
    /// still-filling run, mirroring the admission gate's rescue) — the
    /// "dispatched minus completed work" load estimate routing policies
    /// balance on. A nominal-token estimate would overstate a prefix-warm
    /// replica's load 3-4× and mis-route around exactly the replicas that
    /// serve template traffic cheapest.
    pub fn outstanding_tokens(&self) -> usize {
        let mut total = 0;
        // non-terminal = admitted (active list) + queued (pending list);
        // scanning those instead of every request ever keeps the routed
        // dispatch loop O(live), not O(history)
        for pool in &self.pools {
            for &id in pool.active_ids() {
                let r = pool.get(id);
                total += r.spec.prompt_len.saturating_sub(r.prefilled)
                    + r.spec.decode_len.saturating_sub(r.decoded);
            }
            for &id in pool.queued_ids() {
                let r = pool.get(id);
                let mut eff = r.prefilled;
                if !r.prefix_fallback {
                    if let Some(pfx) = r.spec.prefix.as_ref() {
                        // whole-template coverage when the hash is
                        // registered; otherwise the deepest radix ancestor
                        // the request's content path can attach to (a
                        // still-filling run counts, mirroring admission)
                        let mut cov = self.kv.pool().lookup_prefix_tokens(pfx.id);
                        if cov.is_none() && !pfx.path.is_empty() {
                            let m = self.kv.pool().lookup_path_match(&pfx.path);
                            if m.attach_tokens > 0 {
                                cov = Some(m.attach_tokens);
                            }
                        }
                        if let Some(c) = cov {
                            eff = eff.max(c.min(r.spec.prompt_len.saturating_sub(1)));
                        }
                    }
                }
                total += r.spec.prompt_len.saturating_sub(eff)
                    + r.spec.decode_len.saturating_sub(r.decoded);
            }
        }
        total
    }

    /// Process the single earliest pending event. Returns false when no
    /// stream has a timed event (all Done/Stalled) — the caller then
    /// pushes more arrivals or calls [`resolve_stall`](Self::resolve_stall).
    pub fn step(&mut self) -> bool {
        // next event in global time order; Apply beats Schedule on ties
        // (its completions free blocks "at that instant"), lowest stream
        // index breaks the rest
        let mut pick: Option<(f64, u8, usize)> = None;
        for (i, ev) in self.events.iter().enumerate() {
            let key = match ev {
                Event::Schedule(t) | Event::Idle(t) => (*t, 1u8, i),
                Event::Apply { at, .. } => (*at, 0u8, i),
                Event::Stalled | Event::Done => continue,
            };
            let better = match pick {
                None => true,
                Some(p) => key < p,
            };
            if better {
                pick = Some(key);
            }
        }
        let Some((_, _, si)) = pick else {
            return false;
        };

        match std::mem::replace(&mut self.events[si], Event::Done) {
            Event::Schedule(now) | Event::Idle(now) => self.process_schedule(si, now),
            Event::Apply {
                at,
                batch,
                shape,
                started_at,
                stage_time,
                swap_in,
                batch_id,
                prefix_hits,
                prefix_partial_hits,
                prefix_partial_hit_tokens,
                prefix_fallbacks,
                prefix_wait_iters,
            } => self.process_apply(
                si,
                at,
                batch,
                shape,
                started_at,
                stage_time,
                swap_in,
                batch_id,
                prefix_hits,
                prefix_partial_hits,
                prefix_partial_hit_tokens,
                prefix_fallbacks,
                prefix_wait_iters,
            ),
            Event::Stalled | Event::Done => unreachable!("picked a non-runnable event"),
        }
        true
    }

    fn process_schedule(&mut self, si: usize, now: f64) {
        self.clock = self.clock.max(now);
        // admission: the stream's own policy (dispatching any custom
        // `admit_capped` override, e.g. request-level batching) plus the
        // per-stream cap over the SHARED pool
        self.scheds[si].admit_capped(
            &mut self.pools[si],
            self.kv.pool_mut(),
            now,
            self.per_stream_cap,
        );
        self.result.metrics.rejections += self.pools[si].take_rejected_events();
        self.pending_prefix_hits[si] += self.pools[si].take_prefix_hits();
        self.pending_prefix_partial_hits[si] += self.pools[si].take_prefix_partial_hits();
        self.pending_prefix_partial_hit_tokens[si] +=
            self.pools[si].take_prefix_partial_hit_tokens();
        self.pending_prefix_fallbacks[si] += self.pools[si].take_prefix_fallbacks();
        self.pending_wait_ticks[si] += self.pools[si].take_prefix_wait_ticks();
        self.pending_swap_in[si] +=
            self.sim.applier.swap.swap_in_time(self.pools[si].take_swapped_in_tokens());
        if self.overlap_swaps {
            // swap-in rides the copy stream: compute starts immediately
            self.swap_busy += std::mem::take(&mut self.pending_swap_in[si]);
        }

        let batch = self.scheds[si].compose(&mut self.pools[si], self.kv.pool_mut(), now);
        if batch.is_empty() {
            self.events[si] = if self.pools[si].all_complete() || self.pools[si].is_empty() {
                Event::Done
            } else if let Some(t) = self.pools[si].next_arrival(now) {
                Event::Idle(t)
            } else {
                Event::Stalled
            };
            return;
        }

        let shape = batch.shape(&self.pools[si]);
        let stage_time = self.sim.profiler.predict(&shape);
        let tokens = shape.total_tokens();
        let batch_id = self.result.micro_batches as u64;
        let budget_capped = self.scheds[si].token_budget().is_some_and(|b| tokens >= b);
        // a resumed victim's KV transfer delays entry to stage 0
        let t_swap_in = std::mem::take(&mut self.pending_swap_in[si]);
        let t_prefix_hits = std::mem::take(&mut self.pending_prefix_hits[si]);
        let t_partial_hits = std::mem::take(&mut self.pending_prefix_partial_hits[si]);
        let t_partial_tokens = std::mem::take(&mut self.pending_prefix_partial_hit_tokens[si]);
        let t_fallbacks = std::mem::take(&mut self.pending_prefix_fallbacks[si]);
        let t_wait_ticks = std::mem::take(&mut self.pending_wait_ticks[si]);
        let mut bubble_this_mb = 0.0;
        let mut t_in = now + t_swap_in;
        for j in 0..self.sim.pp {
            let start = t_in.max(self.stage_free[j]);
            let mut gap = 0.0;
            if self.stage_used[j] {
                gap = (start - self.stage_free[j]).max(0.0);
                if gap > 0.0 {
                    bubble_this_mb += gap;
                    self.result.total_bubble += gap;
                    if self.pools[si].trace.is_enabled() {
                        // a stage idling between consecutive micro-batches
                        // is the pipeline-bubble class: waiting on the
                        // barrier of an upstream/late micro-batch
                        let idle_from = self.stage_free[j];
                        self.pools[si].trace.emit_on(
                            idle_from,
                            j as u32,
                            ctrace::EventKind::Bubble {
                                end: start,
                                class: ctrace::BubbleClass::BarrierWait,
                            },
                        );
                    }
                }
            }
            let end = start + stage_time;
            if self.pools[si].trace.is_enabled() {
                self.pools[si].trace.emit_on(
                    start,
                    j as u32,
                    ctrace::EventKind::BatchSpan {
                        batch: batch_id,
                        end,
                        prefill_tokens: shape.prefill_tokens(),
                        decode_tokens: shape.decode_tokens(),
                        n_prefill: shape.prefill.len(),
                        n_decode: shape.decode.len(),
                        budget_capped,
                    },
                );
            }
            if self.sim.trace {
                self.result.trace.push(TraceEvent {
                    micro_batch: self.result.micro_batches,
                    stream: si,
                    stage: j,
                    start,
                    end,
                    gap,
                    tokens: (shape.prefill_tokens(), shape.decode_tokens()),
                });
            }
            self.result.total_busy += stage_time;
            self.stage_free[j] = end;
            self.stage_used[j] = true;
            t_in = end + self.sim.p2p_time(tokens);
        }
        let finish = t_in - self.sim.p2p_time(tokens); // exit of last stage

        // attribute this micro-batch's bubbles to its requests
        for req in batch.request_iter() {
            self.result.bubble_per_request[self.global_ids[si][req]] += bubble_this_mb;
        }
        self.result.micro_batches += 1;
        self.events[si] = Event::Apply {
            at: finish,
            batch,
            shape,
            started_at: now,
            stage_time,
            swap_in: t_swap_in,
            batch_id,
            prefix_hits: t_prefix_hits,
            prefix_partial_hits: t_partial_hits,
            prefix_partial_hit_tokens: t_partial_tokens,
            prefix_fallbacks: t_fallbacks,
            prefix_wait_iters: t_wait_ticks,
        };
    }

    #[allow(clippy::too_many_arguments)]
    fn process_apply(
        &mut self,
        si: usize,
        finish: f64,
        batch: Batch,
        shape: BatchShape,
        started_at: f64,
        stage_time: f64,
        swap_in: f64,
        batch_id: u64,
        prefix_hits: usize,
        prefix_partial_hits: usize,
        prefix_partial_hit_tokens: usize,
        prefix_fallbacks: usize,
        prefix_wait_iters: usize,
    ) {
        self.clock = self.clock.max(finish);
        // requests executing in OTHER streams' in-flight micro-batches are
        // not preemptible (their KV is under the running kernel)
        self.scratch_in_flight.clear();
        for (j, ev) in self.events.iter().enumerate() {
            if let Event::Apply { batch, .. } = ev {
                for r in batch.request_iter() {
                    self.scratch_in_flight.push((j, r));
                }
            }
        }
        // the engine-shared state transition: progress, token stamps,
        // completions, growth, cross-stream preemption
        let effects = self.sim.applier.apply_traced(
            &mut self.pools,
            si,
            self.kv.pool_mut(),
            &batch,
            finish,
            &self.scratch_in_flight,
            batch_id,
        );
        for local in &effects.finished {
            let g = self.global_ids[si][*local];
            self.result.completions[g] = finish;
            self.finish_events.push((g, finish));
        }
        // swap-out either serializes the stream (colocated default) or
        // rides the overlapped copy stream (disaggregated topologies)
        let swap_out = if self.overlap_swaps {
            self.swap_busy += effects.swap_time;
            0.0
        } else {
            effects.swap_time
        };
        // occupancy counts shared-prefix content once: private live tokens
        // + the allocator's resident-prefix tokens
        let private_live: usize = self.pools.iter().map(|p| p.live_private_kv_tokens()).sum();
        self.result.metrics.record(IterationRecord {
            started_at,
            elapsed: stage_time,
            shape,
            prefill_alone: None,
            breakdown: None,
            kv_blocks_in_use: self.kv.pool().allocated(),
            kv_blocks_total: self.kv.pool().capacity(),
            n_active: self.pools.iter().map(|p| p.active_count()).sum(),
            preemptions: effects.preemptions,
            kv_frag_tokens: self.kv.pool().internal_fragmentation(private_live),
            swap_time: swap_in + swap_out,
            rejections: 0,
            prefix_hits,
            prefix_partial_hits,
            prefix_partial_hit_tokens,
            prefix_fallbacks,
            prefix_wait_iters,
            shared_kv_tokens: self.pools.iter().map(|p| p.shared_kv_tokens()).sum(),
        });
        self.result.makespan = self.result.makespan.max(finish);
        // swap-out transfers delay this stream's next schedule (zero when
        // they ride the copy stream instead)
        self.events[si] = Event::Schedule(finish + swap_out);
        // freed blocks may unblock stalled streams: retry them
        for (j, ev) in self.events.iter_mut().enumerate() {
            if j != si && matches!(ev, Event::Stalled) {
                *ev = Event::Schedule(finish);
            }
        }
    }

    /// Resolve a no-timed-events state: if any stream is stalled and some
    /// queued request waits on an in-flight prefix fill, the stall is a
    /// cache-wait cycle, not a true wedge (the ROADMAP's multi-template
    /// cross-stream preemption hole) — force the OLDEST waiter's
    /// full-price fallback and wake every stalled stream; each demotion
    /// permanently retires one waiter, so repeated resolution terminates.
    pub fn resolve_stall(&mut self) -> StallOutcome {
        if !self.events.iter().any(|ev| matches!(ev, Event::Stalled)) {
            return StallOutcome::Idle;
        }
        let waiter = self
            .pools
            .iter()
            .enumerate()
            .filter_map(|(pi, p)| p.oldest_prefix_waiter().map(|id| (pi, id)))
            .min_by(|&(pa, a), &(pb, b)| {
                self.pools[pa]
                    .get(a)
                    .arrival
                    .total_cmp(&self.pools[pb].get(b).arrival)
                    .then(pa.cmp(&pb))
                    .then(a.cmp(&b))
            });
        let Some((pi, id)) = waiter else {
            return StallOutcome::Wedged;
        };
        let clock = self.clock;
        // demote to the deepest READY ancestor on the waiter's content
        // path (0 = plain full-price miss) — same rule as the bounded-wait
        // stall fallback in admission and the engine's wedge demotion
        let ready = match self.pools[pi].get(id).spec.prefix.as_ref() {
            Some(pfx) if !pfx.path.is_empty() => {
                let kv = self.kv.pool();
                let bs = kv.block_size().max(1);
                let cap = self.pools[pi].get(id).spec.prompt_len.saturating_sub(1);
                let kb = (pfx.len.min(cap) / bs).min(pfx.path.len());
                if kb > 0 {
                    kv.lookup_path_match(&pfx.path[..kb]).ready_tokens
                } else {
                    0
                }
            }
            _ => 0,
        };
        self.pools[pi].force_prefix_fallback(id, clock, ready);
        for ev in self.events.iter_mut() {
            if matches!(ev, Event::Stalled) {
                *ev = Event::Schedule(clock);
            }
        }
        StallOutcome::Demoted
    }

    /// Every unfinished stream is stalled with NO waiter to demote:
    /// admitted-but-unschedulable or queued-but-starved requests that no
    /// future event can unblock. Fail loudly like `Engine::run`'s "engine
    /// wedged" panic — a silent `done` here would leave NaN completions
    /// behind.
    pub fn panic_wedged(&self) -> ! {
        // only reachable once no timed events remain, so every live
        // stream is stalled — one count tells the whole story
        let stalled = self.events.iter().filter(|ev| matches!(ev, Event::Stalled)).count();
        let detail: Vec<String> = self
            .pools
            .iter()
            .enumerate()
            .filter(|(_, p)| !p.all_complete())
            .map(|(i, p)| {
                let left = p
                    .iter()
                    .filter(|r| r.completed_at.is_none() && r.rejected_at.is_none())
                    .count();
                format!("stream {i}: {} active, {left} incomplete", p.active_count())
            })
            .collect();
        let waiting: usize = self.pools.iter().map(|p| p.prefix_waiting_count()).sum();
        panic!(
            "pipeline wedged: {stalled} streams stalled with work left ({}); \
             kv {}/{} blocks in use ({} free + {} reclaimable), {waiting} queued \
             requests blocked on a prefix fill",
            detail.join("; "),
            self.kv.pool().allocated(),
            self.kv.pool().capacity(),
            self.kv.pool().available(),
            self.kv.pool().reclaimable(),
        );
    }

    /// Finish the run: flush wait/fallback events observed after each
    /// stream's last recorded micro-batch (e.g. a wedge demotion right
    /// before the end) so the totals stay exact even without a carrier
    /// record, then collect per-request outcomes and the latency report.
    pub fn finish(mut self) -> PipelineResult {
        for (si, pool) in self.pools.iter_mut().enumerate() {
            self.result.metrics.prefix_fallbacks +=
                self.pending_prefix_fallbacks[si] + pool.take_prefix_fallbacks();
            self.result.metrics.prefix_wait_iterations +=
                self.pending_wait_ticks[si] + pool.take_prefix_wait_ticks();
        }
        // per-request liveness outcome, in run-local (push) order
        for (si, pool) in self.pools.iter().enumerate() {
            for r in pool.iter() {
                let g = self.global_ids[si][r.id];
                if let Some(t) = r.first_token_at {
                    self.result.first_tokens[g] = t;
                }
                self.result.prefix_fallback[g] = r.prefix_fallback;
                self.result.max_tbt[g] = r.max_tbt;
            }
        }
        self.result.copy_busy = self.swap_busy;
        self.result.latency = LatencyReport::from_pools(&self.pools);
        // lifecycle events: drain every stream sink, canonical merge
        if self.pools.iter().any(|p| p.trace.is_enabled()) {
            let mut streams = Vec::with_capacity(self.pools.len());
            for pool in &mut self.pools {
                let mut v = Vec::new();
                pool.trace.drain_into(&mut v);
                streams.push(v);
            }
            self.result.events = ctrace::merge_streams(streams);
        }
        // causal latency decomposition, remapped to run-local indices;
        // imported requests (first token stamped prefill-side, before
        // this replica could even see the KV) are the cluster driver's
        // to stitch — a local decomposition would go negative
        for (si, pool) in self.pools.iter().enumerate() {
            for r in pool.iter() {
                if r.first_token_at.is_some_and(|t| t < r.arrival) {
                    continue;
                }
                if let Some(mut bd) =
                    ctrace::LatencyBreakdown::for_request(r, &self.sim.applier.swap, 0.0)
                {
                    bd.request = self.global_ids[si][r.id];
                    self.result.breakdowns.push(bd);
                }
            }
        }
        self.result.breakdowns.sort_by_key(|b| b.request);
        self.result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig, PreemptionMode};
    use crate::coordinator::sched::{
        HybridScheduler, OrcaScheduler, RequestLevelScheduler, SarathiScheduler,
    };
    use crate::costmodel::CostModel;
    use crate::util::Rng;
    use crate::workload::zipf_population;

    fn gpt3_profiler(pp: usize) -> Profiler {
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, pp));
        Profiler::build(CostModel::for_deployment(&d), 4096, 32)
    }

    fn workload(n: usize) -> Vec<RequestSpec> {
        let mut rng = Rng::new(42);
        zipf_population(&mut rng, n, 0.4, 1024, 4096, 10.0)
    }

    #[test]
    fn completes_every_request() {
        let sim = PipelineSim::new(gpt3_profiler(4), 4);
        let specs = workload(24);
        let res = sim.run(&specs, 8, || Box::new(SarathiScheduler::new(256, 8, 128)));
        assert_eq!(res.completions.len(), 24);
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.makespan > 0.0);
        assert!(res.micro_batches > 0);
    }

    #[test]
    fn single_stage_has_no_bubbles() {
        let sim = PipelineSim::new(gpt3_profiler(1), 1);
        let specs = workload(12);
        let res = sim.run(&specs, 8, || Box::new(OrcaScheduler::best(8)));
        // one stage, one stream: back-to-back execution, zero gaps
        assert_eq!(res.total_bubble, 0.0);
        assert!((res.utilization() - 1.0).abs() < 1e-9);
    }

    /// The paper's Fig.-12 headline: SARATHI's uniform micro-batches cut
    /// pipeline bubbles by several × vs Orca-style scheduling and speed up
    /// the end-to-end run by ~1.9×. Requires a steady-state workload
    /// (requests ≫ in-flight slots) so prefills keep interleaving with
    /// decodes — the condition that creates PB1/PB2 bubbles.
    #[test]
    fn sarathi_reduces_bubbles_vs_orca() {
        let specs = workload(400);
        let sim = PipelineSim::new(gpt3_profiler(8), 8);
        let orca = sim.run(&specs, 27, || Box::new(OrcaScheduler::best(27)));
        let sar = sim.run(&specs, 27, || Box::new(SarathiScheduler::new(256, 27, 128)));
        let med = |r: &PipelineResult| r.bubble_summary().percentile(50.0);
        assert!(
            med(&sar) < med(&orca) / 5.0,
            "median bubble: sarathi={} orca={}",
            med(&sar),
            med(&orca)
        );
        // end-to-end speedup in the paper's ballpark (1.91×)
        let speedup = orca.makespan / sar.makespan;
        assert!((1.4..2.6).contains(&speedup), "speedup={speedup}");
    }

    /// Regression: `run_shared` must dispatch admission through the
    /// scheduler's `admit_capped` override — driving the gate directly
    /// bypassed RequestLevelScheduler's custom batch admission, left its
    /// `running` list empty, and wedged every stream.
    #[test]
    fn request_level_baseline_works_in_pipeline_mode() {
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let specs = workload(8);
        let res = sim.run(&specs, 4, || Box::new(RequestLevelScheduler::new(4)));
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.latency.ttft.count() == 8);
    }

    #[test]
    fn completion_curve_is_monotone() {
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let res = sim.run(&workload(10), 8, || Box::new(SarathiScheduler::new(256, 8, 128)));
        let curve = res.completion_curve();
        assert_eq!(curve.len(), 10);
        assert!(curve.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn bubbles_are_nonnegative_and_bounded() {
        let sim = PipelineSim::new(gpt3_profiler(8), 8);
        let res = sim.run(&workload(24), 27, || Box::new(OrcaScheduler::best(27)));
        assert!(res.bubble_per_request.iter().all(|&b| b >= 0.0));
        assert!(res.total_bubble <= res.makespan * 8.0);
    }

    #[test]
    fn pipeline_latency_report_is_populated() {
        // the seed's drifted apply never stamped token times, so TBT was
        // silently empty for every pipeline run; the shared StepApplier
        // fixes that
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let specs = workload(12);
        let res = sim.run(&specs, 8, || Box::new(SarathiScheduler::new(256, 8, 128)));
        assert_eq!(res.latency.ttft.count(), 12, "every request has a TTFT");
        assert!(res.latency.tbt.count() > 0, "TBT gaps are stamped");
        assert_eq!(res.latency.normalized.count(), 12);
        assert!(res.latency.ttft.min() > 0.0);
        // metrics mirror the run: one record per micro-batch
        assert_eq!(res.metrics.recorded_count(), res.micro_batches);
    }

    /// Shared tight setup for the preemption tests: 8 requests whose peak
    /// demand (8 × 704 tokens) far exceeds the 16-block × 128-token pool,
    /// so decode growth must preempt — across streams, since both draw
    /// from the one pool. (Margins mirror-validated: 7 preemption events.)
    fn tight_specs() -> Vec<RequestSpec> {
        (0..8)
            .map(|i| RequestSpec {
                prompt_len: 512,
                decode_len: 192,
                arrival: i as f64 * 0.01,
                prefix: None,
            })
            .collect()
    }

    #[test]
    fn shared_paged_pool_preempts_across_streams_and_completes() {
        let pp = 2;
        let sim = PipelineSim::new(gpt3_profiler(pp), pp);
        let res = sim.run_shared(&tight_specs(), KvManager::paged(16, 128), Some(4), || {
            Box::new(HybridScheduler::new(256, 4, 0)) as Box<dyn Scheduler + Send>
        });
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.metrics.preemptions > 0, "undersized shared pool must preempt");
        assert_eq!(res.metrics.total_swap_time(), 0.0, "default swaps are free");
    }

    #[test]
    fn costed_swaps_surface_in_pipeline_metrics() {
        let pp = 2;
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, pp));
        let sim = PipelineSim::new(gpt3_profiler(pp), pp)
            .with_swap_cost(SwapCost::for_deployment(&d, PreemptionMode::Swap));
        let free_sim = PipelineSim::new(gpt3_profiler(pp), pp);
        let specs = tight_specs();
        let kv = || KvManager::paged(16, 128);
        let sched =
            || Box::new(HybridScheduler::new(256, 4, 0)) as Box<dyn Scheduler + Send>;
        let costed = sim.run_shared(&specs, kv(), Some(4), sched);
        let free = free_sim.run_shared(&specs, kv(), Some(4), sched);
        assert!(costed.metrics.preemptions > 0);
        assert!(costed.metrics.total_swap_time() > 0.0, "swap time must be charged");
        assert!(
            costed.makespan > free.makespan,
            "paying for KV movement must stretch the run: {} !> {}",
            costed.makespan,
            free.makespan
        );
    }

    /// Prefix sharing threads through the pipeline unchanged: all streams
    /// draw from ONE shared pool, so a template registered by stream 0's
    /// first arrival is hit by sharers scheduled on stream 1.
    #[test]
    fn shared_prefix_templates_hit_across_streams_over_one_pool() {
        use crate::workload::shared_prefix_population;
        let pp = 2;
        let sim = PipelineSim::new(gpt3_profiler(pp), pp);
        let mut rng = Rng::new(11);
        let specs = shared_prefix_population(&mut rng, 32, 4, 0.8, 256, 32, 128, 5.0);
        let res = sim.run_shared(&specs, KvManager::paged(96, 128), Some(8), || {
            Box::new(HybridScheduler::new(256, 8, 2).with_prefix_share(true))
                as Box<dyn Scheduler + Send>
        });
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.metrics.prefix_hits > 0, "cross-stream sharers must hit");
        assert!(res.metrics.peak_shared_kv_tokens() > 0);
        // block accounting: at the end only resident prefix pins remain
        let last = res.metrics.last_record().unwrap();
        assert!(last.kv_blocks_in_use <= 4 * 2, "only pinned prefix runs may remain");
    }

    /// A scheduler that admits but never composes work: the admitted
    /// requests are unschedulable forever, which must fail loudly.
    struct NullScheduler;
    impl Scheduler for NullScheduler {
        fn compose(&mut self, _: &mut RequestPool, _: &mut KvManager, _: f64) -> Batch {
            Batch::default()
        }
        fn name(&self) -> &'static str {
            "null"
        }
    }

    #[test]
    #[should_panic(expected = "pipeline wedged")]
    fn admitted_but_unschedulable_requests_panic_loudly() {
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let specs = workload(4);
        let _ = sim.run(&specs, 4, || Box::new(NullScheduler) as Box<dyn Scheduler + Send>);
    }

    /// The wedged message now carries the diagnostics that hid this bug
    /// class: KV occupancy, free + reclaimable funds, and how many queued
    /// requests are blocked on a prefix fill.
    #[test]
    #[should_panic(expected = "blocked on a prefix fill")]
    fn wedged_panic_reports_kv_and_prefix_wait_diagnostics() {
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let specs = workload(4);
        let _ = sim.run(&specs, 4, || Box::new(NullScheduler) as Box<dyn Scheduler + Send>);
    }

    /// Tentpole guarantee (3), pipeline side — the exact ROADMAP hole,
    /// reconstructed deterministically. Stream 0's template registrant is
    /// growth-preempted at ZERO progress (admitted, but budget-starved
    /// out of every batch), so on resume it waits on its own unready run;
    /// stream 1's same-template arrival waits on it too. PR-3 panicked
    /// "pipeline wedged" here (all streams stalled); now the driver
    /// demotes the oldest waiter to a full-price fallback, wakes the
    /// stalled streams, and every request completes. `max_prefix_wait` is
    /// set huge so BOTH resolutions exercise the demotion path, not the
    /// bounded-wait expiry.
    #[test]
    fn circular_cache_wait_demotes_to_fallback_instead_of_wedging() {
        use crate::workload::PrefixSpec;
        let tpl = |arrival: f64| RequestSpec {
            prompt_len: 40,
            decode_len: 4,
            arrival,
            prefix: Some(PrefixSpec::whole(1, 32)),
        };
        let specs = vec![
            // stream 0: a plain request whose 32-token budget chunks starve
            // the registrant, then whose decode growth evicts it
            RequestSpec { prompt_len: 96, decode_len: 16, arrival: 0.0, prefix: None },
            // stream 1: a same-template arrival, long after the storm
            tpl(5.0),
            // stream 0: the registrant, arriving just after the first batch
            tpl(0.001),
        ];
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let res = sim.run_shared(&specs, KvManager::paged(9, 16), None, || {
            Box::new(
                HybridScheduler::new(32, 8, 0)
                    .with_prefix_share(true)
                    .with_max_prefix_wait(1_000),
            ) as Box<dyn Scheduler + Send>
        });
        assert!(res.completions.iter().all(|t| !t.is_nan()), "no request starves");
        assert!(res.first_tokens.iter().all(|t| !t.is_nan()));
        assert_eq!(res.metrics.preemptions, 1, "the registrant was evicted once");
        assert_eq!(res.metrics.prefix_fallbacks, 2, "both waiters were demoted");
        assert_eq!(res.metrics.prefix_hits, 0, "the run never became servable");
        assert!(res.metrics.prefix_wait_iterations > 0);
        assert_eq!(res.prefix_fallback, vec![false, true, true]);
    }

    /// The resumable stepping API underlying both drivers: pushes wake
    /// idle streams, `next_event_time` exposes the replica clock, and the
    /// cache-aware outstanding-work estimate discounts queued template
    /// traffic by resident coverage.
    #[test]
    fn pipeline_run_steps_incrementally_with_late_pushes() {
        let sim = PipelineSim::new(gpt3_profiler(1), 1);
        let mut make =
            || Box::new(SarathiScheduler::new(256, 8, 128)) as Box<dyn Scheduler + Send>;
        let mut run = PipelineRun::new(&sim, KvManager::new(8), Some(8), &mut make);
        assert_eq!(run.outstanding_tokens(), 0);
        let spec = RequestSpec { prompt_len: 100, decode_len: 10, arrival: 0.0, prefix: None };
        run.push(spec);
        assert_eq!(run.outstanding_tokens(), 110);
        // drive to quiescence
        while run.step() {}
        assert_eq!(run.resolve_stall(), StallOutcome::Idle);
        assert!(run.is_complete());
        assert_eq!(run.outstanding_tokens(), 0);
        let t1 = run.next_event_time();
        assert!(t1.is_none(), "no events left after completion");
        // a late push wakes the Done stream at its arrival
        let late = RequestSpec { prompt_len: 50, decode_len: 5, arrival: 100.0, prefix: None };
        run.push(late);
        assert_eq!(run.next_event_time(), Some(100.0));
        while run.step() {}
        let res = run.finish();
        assert_eq!(res.completions.len(), 2);
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.completions[1] > 100.0);
    }

    /// The handoff import edge: a request whose KV arrives over the
    /// interconnect enters decode-ready at the transfer's finish time —
    /// admission never sees it earlier — produces only its remaining
    /// decode tokens, keeps TTFT off this replica's books (the prefill
    /// side owns it), and surfaces the transfer + queueing latency in its
    /// max TBT gap. `take_finished` exposes the completion for the driver.
    #[test]
    fn imported_requests_wait_for_their_transfer_arrival() {
        let sim = PipelineSim::new(gpt3_profiler(1), 1);
        let mut make =
            || Box::new(SarathiScheduler::new(256, 8, 128)) as Box<dyn Scheduler + Send>;
        let mut run = PipelineRun::with_streams(&sim, KvManager::new(8), Some(8), &mut make, 1);
        let spec = RequestSpec { prompt_len: 100, decode_len: 5, arrival: 2.0, prefix: None };
        run.push_imported(0, spec, 1.5);
        assert_eq!(run.next_event_time(), Some(2.0), "invisible before the KV lands");
        while run.step() {}
        let finished = run.take_finished();
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].0, 0);
        assert!(finished[0].1 >= 2.0);
        assert!(run.take_finished().is_empty(), "events drain");
        let res = run.finish();
        assert!(res.completions[0] >= 2.0, "decode cannot precede the transfer");
        assert_eq!(res.latency.ttft.count(), 0, "TTFT belongs to the prefill side");
        // 4 decode gaps stamped; the first spans transfer + admission wait
        assert_eq!(res.latency.tbt.count(), 4);
        assert!(res.max_tbt[0] > 0.5 - 1e-9, "gap from the prefill-side first token");
    }

    /// RAPID-Serve-style intra-replica split: two lanes time-share one
    /// stage's compute. Work pinned per lane completes on both, and the
    /// stage serializes the lanes (busy time never exceeds the makespan).
    #[test]
    fn split_lanes_time_share_one_stage() {
        let sim = PipelineSim::new(gpt3_profiler(1), 1);
        let mut make =
            || Box::new(SarathiScheduler::new(128, 4, 128)) as Box<dyn Scheduler + Send>;
        let mut run = PipelineRun::with_streams(&sim, KvManager::new(8), Some(4), &mut make, 2);
        for (i, spec) in workload(8).into_iter().enumerate() {
            run.push_to(i % 2, spec);
        }
        while run.step() {}
        assert_eq!(run.resolve_stall(), StallOutcome::Idle);
        let res = run.finish();
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(
            res.total_busy <= res.makespan + 1e-9,
            "one stage: lanes serialize, busy {} vs makespan {}",
            res.total_busy,
            res.makespan
        );
    }

    /// Swap migration to the copy stream: with overlap on, preemption
    /// transfers stop serializing compute (zero recorded swap time, a
    /// shorter run) and show up as copy-stream busy time instead.
    #[test]
    fn overlapped_swaps_ride_the_copy_stream_not_the_compute_clock() {
        let pp = 2;
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, pp));
        let sim = PipelineSim::new(gpt3_profiler(pp), pp)
            .with_swap_cost(SwapCost::for_deployment(&d, PreemptionMode::Swap));
        let drive = |overlap: bool| {
            let mut make =
                || Box::new(HybridScheduler::new(256, 4, 0)) as Box<dyn Scheduler + Send>;
            let mut run =
                PipelineRun::new(&sim, KvManager::paged(16, 128), Some(4), &mut make);
            run.set_overlap_swaps(overlap);
            for spec in tight_specs() {
                run.push(spec);
            }
            loop {
                if run.step() {
                    continue;
                }
                match run.resolve_stall() {
                    StallOutcome::Demoted => continue,
                    StallOutcome::Wedged => run.panic_wedged(),
                    StallOutcome::Idle => break,
                }
            }
            run.finish()
        };
        let serialized = drive(false);
        let overlapped = drive(true);
        assert!(serialized.metrics.total_swap_time() > 0.0);
        assert_eq!(serialized.copy_busy, 0.0);
        assert!(overlapped.metrics.preemptions > 0);
        assert_eq!(overlapped.metrics.total_swap_time(), 0.0, "nothing serializes");
        assert!(overlapped.copy_busy > 0.0, "the charge moved to the copy stream");
        assert!(
            overlapped.makespan < serialized.makespan,
            "overlap must shorten the run: {} !< {}",
            overlapped.makespan,
            serialized.makespan
        );
    }
}
