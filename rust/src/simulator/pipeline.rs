//! Discrete-event pipeline-parallel simulator with bubble accounting.
//!
//! Model (§2.3, §3.2): one replica = `pp` stages, each owning
//! `layers/pp` layers (tensor-parallel `tp`-wide inside). Iteration-level
//! scheduling keeps `pp` independent micro-batch *streams* in flight — a
//! stream's next iteration can only be scheduled after its previous
//! micro-batch leaves the last stage (the autoregressive dependency), which
//! is exactly why Orca needs ≥ pp concurrent request groups to fill the
//! pipeline (Fig. 5 runs two groups, A/B and C/D).
//!
//! A **bubble** is any idle gap on a stage between two consecutive
//! micro-batches while work is still pending — caused by micro-batch
//! execution-time variance (PB1: consecutive prefills of different length;
//! PB2: prefill followed by decode; PB3: decode KV-length variance). The
//! simulator attributes each gap to the requests of the micro-batch whose
//! late arrival caused it, giving the paper's per-request bubble metric
//! (Fig. 12a).

use crate::coordinator::{Batch, KvManager, RequestPool, Scheduler};
use crate::profiler::Profiler;
use crate::util::Summary;
use crate::workload::RequestSpec;

/// One stage-execution event, for schedule traces (Fig. 5).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub micro_batch: usize,
    pub stream: usize,
    pub stage: usize,
    pub start: f64,
    pub end: f64,
    /// Idle gap on this stage immediately before this event.
    pub gap: f64,
    /// Composition summary: (prefill tokens, decode tokens).
    pub tokens: (usize, usize),
}

/// Outcome of one pipeline run.
#[derive(Clone, Debug, Default)]
pub struct PipelineResult {
    /// Total simulated time until the last request completes.
    pub makespan: f64,
    /// Completion time per request (absolute, seconds).
    pub completions: Vec<f64>,
    /// Per-request accumulated bubble time (Fig. 12a's metric).
    pub bubble_per_request: Vec<f64>,
    /// Total stage-idle (bubble) time across all stages.
    pub total_bubble: f64,
    /// Total busy time across all stages (for utilization).
    pub total_busy: f64,
    /// Number of micro-batches executed.
    pub micro_batches: usize,
    /// Per-stage schedule trace (recorded when `PipelineSim::trace` is on).
    pub trace: Vec<TraceEvent>,
}

impl PipelineResult {
    pub fn bubble_summary(&self) -> Summary {
        let mut s = Summary::new();
        for &b in &self.bubble_per_request {
            s.add(b);
        }
        s
    }

    /// Sorted completion curve: (i+1 requests done, time) — Fig. 12b.
    pub fn completion_curve(&self) -> Vec<(usize, f64)> {
        let mut c = self.completions.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        c.into_iter().enumerate().map(|(i, t)| (i + 1, t)).collect()
    }

    pub fn utilization(&self) -> f64 {
        if self.total_busy + self.total_bubble == 0.0 {
            0.0
        } else {
            self.total_busy / (self.total_busy + self.total_bubble)
        }
    }
}

/// One in-flight stream: its own scheduler/pool/kv over a partition of the
/// workload.
struct Stream<'a> {
    pool: RequestPool,
    kv: KvManager,
    scheduler: Box<dyn Scheduler + 'a>,
    /// Global request ids (indices into the input spec slice) per local id.
    global_ids: Vec<usize>,
    /// Time at which this stream may schedule its next iteration.
    ready_at: f64,
    done: bool,
}

/// Pipeline-parallel simulator for one replica.
pub struct PipelineSim {
    pub profiler: Profiler,
    pub pp: usize,
    /// Record a full per-stage schedule trace (Fig. 5 demonstrations).
    pub trace: bool,
    /// Hidden size × bytes for activation transfer between stages.
    act_bytes_per_token: f64,
    p2p_bw: f64,
}

impl PipelineSim {
    /// `profiler` must be built from a per-STAGE cost model
    /// (`CostModel::for_deployment` divides layers by pp).
    pub fn new(profiler: Profiler, pp: usize) -> Self {
        let cm = profiler.cost_model();
        let act_bytes_per_token = (cm.model.hidden * cm.model.bytes_per_param) as f64;
        let p2p_bw = cm.gpu.p2p_bw_gbps * 1e9;
        PipelineSim { profiler, pp, trace: false, act_bytes_per_token, p2p_bw }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    fn p2p_time(&self, tokens: usize) -> f64 {
        if self.pp == 1 {
            return 0.0;
        }
        tokens as f64 * self.act_bytes_per_token / self.p2p_bw
    }

    /// Run the workload to completion. `make_sched` builds one scheduler
    /// per stream; `slots_per_stream` bounds each stream's batch.
    pub fn run<'a, F>(
        &self,
        specs: &[RequestSpec],
        slots_per_stream: usize,
        mut make_sched: F,
    ) -> PipelineResult
    where
        F: FnMut() -> Box<dyn Scheduler + 'a>,
    {
        let n_streams = self.pp.max(1);
        // partition requests round-robin across streams
        let mut streams: Vec<Stream> = (0..n_streams)
            .map(|_| Stream {
                pool: RequestPool::new(),
                kv: KvManager::new(slots_per_stream),
                scheduler: make_sched(),
                global_ids: Vec::new(),
                ready_at: 0.0,
                done: false,
            })
            .collect();
        for (g, &spec) in specs.iter().enumerate() {
            let s = &mut streams[g % n_streams];
            s.pool.push(spec);
            s.global_ids.push(g);
        }

        let mut stage_free = vec![0.0f64; self.pp];
        let mut stage_used = vec![false; self.pp];
        let mut result = PipelineResult {
            completions: vec![f64::NAN; specs.len()],
            bubble_per_request: vec![0.0; specs.len()],
            ..Default::default()
        };

        loop {
            // next stream to inject: smallest ready_at among unfinished,
            // FIFO on ties (stable index order)
            let mut pick: Option<usize> = None;
            for (i, s) in streams.iter().enumerate() {
                if s.done {
                    continue;
                }
                if pick.is_none() || s.ready_at < streams[pick.unwrap()].ready_at {
                    pick = Some(i);
                }
            }
            let Some(si) = pick else { break };

            // schedule this stream's next micro-batch
            let (batch, now) = {
                let s = &mut streams[si];
                let now = s.ready_at;
                let b = s.scheduler.schedule(&mut s.pool, &mut s.kv, now);
                (b, now)
            };
            if batch.is_empty() {
                let s = &mut streams[si];
                if s.pool.all_complete() || s.pool.is_empty() {
                    s.done = true;
                    continue;
                }
                // idle until the next arrival in this stream
                if let Some(t) = s.pool.next_arrival(now) {
                    s.ready_at = t;
                    continue;
                }
                s.done = true; // nothing left to do
                continue;
            }

            let shape = batch.shape(&streams[si].pool);
            let stage_time = self.profiler.predict(&shape);
            let tokens = shape.total_tokens();
            let mut bubble_this_mb = 0.0;
            let mut t_in = now; // micro-batch available at stage 0 at `now`
            for j in 0..self.pp {
                let start = t_in.max(stage_free[j]);
                let mut gap = 0.0;
                if stage_used[j] {
                    gap = (start - stage_free[j]).max(0.0);
                    if gap > 0.0 {
                        bubble_this_mb += gap;
                        result.total_bubble += gap;
                    }
                }
                let end = start + stage_time;
                if self.trace {
                    result.trace.push(TraceEvent {
                        micro_batch: result.micro_batches,
                        stream: si,
                        stage: j,
                        start,
                        end,
                        gap,
                        tokens: (shape.prefill_tokens(), shape.decode_tokens()),
                    });
                }
                result.total_busy += stage_time;
                stage_free[j] = end;
                stage_used[j] = true;
                t_in = end + self.p2p_time(tokens);
            }
            let finish = t_in - self.p2p_time(tokens); // exit of last stage

            // apply results + attribute bubbles
            let s = &mut streams[si];
            let touched = batch.requests();
            for &req in &touched {
                result.bubble_per_request[s.global_ids[req]] += bubble_this_mb;
            }
            let finished = Self::apply(&mut s.pool, &mut s.kv, &batch, finish);
            for local in finished {
                result.completions[s.global_ids[local]] = finish;
            }
            s.ready_at = finish;
            result.micro_batches += 1;
            result.makespan = result.makespan.max(finish);
        }
        result
    }

    /// Same state transition as `Engine::apply`; returns newly-completed
    /// local request ids.
    fn apply(pool: &mut RequestPool, kv: &mut KvManager, batch: &Batch, now: f64) -> Vec<usize> {
        for (req, _start, len) in batch.prefill_items() {
            let r = pool.get_mut(req);
            r.prefilled += len;
            if r.prefilled == r.spec.prompt_len {
                r.decoded = 1;
                r.first_token_at = Some(now);
            }
        }
        for req in batch.decode_items() {
            pool.get_mut(req).decoded += 1;
        }
        let mut finished = Vec::new();
        for req in batch.requests() {
            let r = pool.get(req);
            if r.completed_at.is_none()
                && r.prefilled == r.spec.prompt_len
                && r.decoded >= r.spec.decode_len
            {
                let blocks = pool.complete(req, now);
                kv.release_seq(blocks);
                finished.push(req);
            }
        }
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
    use crate::coordinator::sched::{OrcaScheduler, SarathiScheduler};
    use crate::costmodel::CostModel;
    use crate::util::Rng;
    use crate::workload::zipf_population;

    fn gpt3_profiler(pp: usize) -> Profiler {
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, pp));
        Profiler::build(CostModel::for_deployment(&d), 4096, 32)
    }

    fn workload(n: usize) -> Vec<RequestSpec> {
        let mut rng = Rng::new(42);
        zipf_population(&mut rng, n, 0.4, 1024, 4096, 10.0)
    }

    #[test]
    fn completes_every_request() {
        let sim = PipelineSim::new(gpt3_profiler(4), 4);
        let specs = workload(24);
        let res = sim.run(&specs, 8, || Box::new(SarathiScheduler::new(256, 8, 128)));
        assert_eq!(res.completions.len(), 24);
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.makespan > 0.0);
        assert!(res.micro_batches > 0);
    }

    #[test]
    fn single_stage_has_no_bubbles() {
        let sim = PipelineSim::new(gpt3_profiler(1), 1);
        let specs = workload(12);
        let res = sim.run(&specs, 8, || Box::new(OrcaScheduler::best(8)));
        // one stage, one stream: back-to-back execution, zero gaps
        assert_eq!(res.total_bubble, 0.0);
        assert!((res.utilization() - 1.0).abs() < 1e-9);
    }

    /// The paper's Fig.-12 headline: SARATHI's uniform micro-batches cut
    /// pipeline bubbles by several × vs Orca-style scheduling and speed up
    /// the end-to-end run by ~1.9×. Requires a steady-state workload
    /// (requests ≫ in-flight slots) so prefills keep interleaving with
    /// decodes — the condition that creates PB1/PB2 bubbles.
    #[test]
    fn sarathi_reduces_bubbles_vs_orca() {
        let specs = workload(400);
        let sim = PipelineSim::new(gpt3_profiler(8), 8);
        let orca = sim.run(&specs, 27, || Box::new(OrcaScheduler::best(27)));
        let sar = sim.run(&specs, 27, || Box::new(SarathiScheduler::new(256, 27, 128)));
        let med = |r: &PipelineResult| r.bubble_summary().percentile(50.0);
        assert!(
            med(&sar) < med(&orca) / 5.0,
            "median bubble: sarathi={} orca={}",
            med(&sar),
            med(&orca)
        );
        // end-to-end speedup in the paper's ballpark (1.91×)
        let speedup = orca.makespan / sar.makespan;
        assert!((1.4..2.6).contains(&speedup), "speedup={speedup}");
    }

    #[test]
    fn completion_curve_is_monotone() {
        let sim = PipelineSim::new(gpt3_profiler(2), 2);
        let res = sim.run(&workload(10), 8, || Box::new(SarathiScheduler::new(256, 8, 128)));
        let curve = res.completion_curve();
        assert_eq!(curve.len(), 10);
        assert!(curve.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn bubbles_are_nonnegative_and_bounded() {
        let sim = PipelineSim::new(gpt3_profiler(8), 8);
        let res = sim.run(&workload(24), 27, || Box::new(OrcaScheduler::best(27)));
        assert!(res.bubble_per_request.iter().all(|&b| b >= 0.0));
        assert!(res.total_bubble <= res.makespan * 8.0);
    }
}
