//! Cluster-level request routing: which replica serves which request.
//!
//! The dispatch layer is its own optimization surface (DistServe's
//! goodput framing, SGLang's cache-aware load balancing): per-replica
//! scheduling can be stall-free and cache-aware, but if the *router*
//! sprays template traffic round-robin, every replica re-pays every
//! prefix and the cluster-wide hit rate collapses to 1/R of what the
//! workload offers. [`ClusterSim::run_routed`] dispatches arrivals one at
//! a time through a [`RoutePolicy`]:
//!
//! * [`RoundRobin`] — the baseline; reproduces the old static `g % R`
//!   partition byte-for-byte on an arrival-sorted workload.
//! * [`LeastOutstandingTokens`] — join-shortest-queue by each replica's
//!   cache-aware outstanding work ([`ReplicaView::outstanding_tokens`]).
//! * [`PrefixAffinity`] — rendezvous-hash the template's prefix hash to a
//!   *home* replica so its pinned run is registered once and every
//!   follower hits it, with a power-of-two-choices load shed to the
//!   second-ranked replica when the home's backlog exceeds
//!   `spill_factor ×` the second's. A shed request simply misses and
//!   admits full-price on the alternate (registering the template there —
//!   emergent hot-prefix replication), so shedding can never wedge a
//!   waiter chain.
//!
//! Rendezvous (highest-random-weight) hashing gives the stability the
//! prefix cache needs: adding a replica re-homes only ~1/(R+1) of the
//! templates (each moved template's new home IS the new replica), so a
//! scale-out does not cold-start every replica's prefix index the way
//! mod-R hashing would.
//!
//! [`ClusterSim::run_routed`]: super::cluster::ClusterSim::run_routed

use crate::util::mix64;
use crate::workload::RequestSpec;

/// What a routing policy sees of one replica at dispatch time.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaView {
    /// Cache-aware outstanding work: prefill + decode tokens the replica
    /// still has to compute for its dispatched, non-terminal requests
    /// (queued template traffic discounted by resident prefix coverage —
    /// see `PipelineRun::outstanding_tokens`).
    pub outstanding_tokens: usize,
}

/// A pluggable dispatch policy: pick the replica for one arriving request
/// given a consistent snapshot of every replica's load.
pub trait RoutePolicy {
    fn route(&mut self, spec: &RequestSpec, views: &[ReplicaView]) -> usize;
    fn name(&self) -> &'static str;
}

/// Arrival-order round-robin — the pre-router baseline.
#[derive(Clone, Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn route(&mut self, _spec: &RequestSpec, views: &[ReplicaView]) -> usize {
        let ri = self.next % views.len().max(1);
        self.next = self.next.wrapping_add(1);
        ri
    }

    fn name(&self) -> &'static str {
        "rr"
    }
}

/// Join-shortest-queue by outstanding work tokens (ties → lowest index).
#[derive(Clone, Copy, Debug, Default)]
pub struct LeastOutstandingTokens;

impl LeastOutstandingTokens {
    pub fn new() -> Self {
        Self
    }

    /// Lowest outstanding-token count, ties → lowest index. Shared by the
    /// routing policy and the disaggregation driver's decode-side handoff
    /// choice (which replica receives a finished prompt's KV).
    pub fn least(views: &[ReplicaView]) -> usize {
        views
            .iter()
            .enumerate()
            .min_by_key(|(i, v)| (v.outstanding_tokens, *i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

impl RoutePolicy for LeastOutstandingTokens {
    fn route(&mut self, _spec: &RequestSpec, views: &[ReplicaView]) -> usize {
        Self::least(views)
    }

    fn name(&self) -> &'static str {
        "jsq"
    }
}

/// Rendezvous-hash prefix affinity with a power-of-two-choices spill.
///
/// A tagged request goes to its template's home (top rendezvous rank)
/// unless the home's outstanding work exceeds `spill_factor ×` the
/// second-ranked replica's, in which case it sheds to the second. At the
/// default `spill_factor = 1.0` this is classic power-of-two-choices over
/// the template's top-2 replicas (strictly-greater comparison, ties stay
/// home); larger factors trade balance for stickiness. Untagged requests
/// fall through to join-shortest-queue over all replicas.
#[derive(Clone, Copy, Debug)]
pub struct PrefixAffinity {
    /// Shed to the second-ranked replica when
    /// `home_outstanding > spill_factor × second_outstanding`.
    pub spill_factor: f64,
}

impl PrefixAffinity {
    /// Default spill factor: plain power-of-two-choices over the top-2.
    pub const DEFAULT_SPILL: f64 = 1.0;

    pub fn new(spill_factor: f64) -> Self {
        assert!(spill_factor >= 0.0, "spill factor must be non-negative");
        PrefixAffinity { spill_factor }
    }
}

impl Default for PrefixAffinity {
    fn default() -> Self {
        Self::new(Self::DEFAULT_SPILL)
    }
}

impl RoutePolicy for PrefixAffinity {
    fn route(&mut self, spec: &RequestSpec, views: &[ReplicaView]) -> usize {
        if views.len() <= 1 {
            return 0;
        }
        let Some(pfx) = spec.prefix else {
            return LeastOutstandingTokens::least(views);
        };
        let (home, second) = rendezvous_top2(pfx.id, views.len());
        let h = views[home].outstanding_tokens as f64;
        let s = views[second].outstanding_tokens as f64;
        if h > self.spill_factor * s {
            second
        } else {
            home
        }
    }

    fn name(&self) -> &'static str {
        "affinity"
    }
}

const GOLD: u64 = 0x9E3779B97F4A7C15;

/// Rendezvous score of `key` on replica `ri`: one SplitMix64 step (the
/// golden-ratio increment plus [`mix64`] — the same mixer `util::Rng`
/// seeds with) over the key/replica combination.
fn score(key: u64, ri: usize) -> u64 {
    mix64((key ^ (ri as u64).wrapping_mul(GOLD)).wrapping_add(GOLD))
}

/// Replica indices ranked by rendezvous (highest-random-weight) score for
/// `key`, best first. Deterministic; ties broken by lowest index.
pub fn rendezvous_rank(key: u64, replicas: usize) -> Vec<usize> {
    let mut scored: Vec<(u64, usize)> = (0..replicas).map(|ri| (score(key, ri), ri)).collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().map(|(_, ri)| ri).collect()
}

/// The top-2 of [`rendezvous_rank`] without the allocation or the sort —
/// what the per-request routing hot path actually needs. Requires
/// `replicas >= 2`.
pub fn rendezvous_top2(key: u64, replicas: usize) -> (usize, usize) {
    debug_assert!(replicas >= 2, "top-2 needs at least two replicas");
    let mut best = (0u64, 0usize);
    let mut second = (0u64, 0usize);
    for ri in 0..replicas {
        let s = score(key, ri);
        // ascending index + strict > reproduces the rank's lowest-index
        // tie-break exactly
        if ri == 0 || s > best.0 {
            if ri > 0 {
                second = best;
            }
            best = (s, ri);
        } else if ri == 1 || s > second.0 {
            second = (s, ri);
        }
    }
    (best.1, second.1)
}

/// CLI-facing router selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RouterKind {
    RoundRobin,
    /// Join-shortest-queue by outstanding tokens.
    Jsq,
    /// Rendezvous-hash prefix affinity with power-of-two spill.
    Affinity,
}

impl RouterKind {
    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "rr",
            RouterKind::Jsq => "jsq",
            RouterKind::Affinity => "affinity",
        }
    }

    /// Parse a CLI name (the inverse of [`name`](Self::name)).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rr" | "round-robin" => RouterKind::RoundRobin,
            "jsq" | "least-outstanding" => RouterKind::Jsq,
            "affinity" => RouterKind::Affinity,
            _ => return None,
        })
    }

    /// Build the policy. `spill_factor` only shapes [`PrefixAffinity`].
    pub fn build(&self, spill_factor: f64) -> Box<dyn RoutePolicy> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin::new()),
            RouterKind::Jsq => Box::new(LeastOutstandingTokens::new()),
            RouterKind::Affinity => Box::new(PrefixAffinity::new(spill_factor)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::PrefixSpec;

    fn views(outstanding: &[usize]) -> Vec<ReplicaView> {
        outstanding.iter().map(|&t| ReplicaView { outstanding_tokens: t }).collect()
    }

    fn tagged(id: u64) -> RequestSpec {
        RequestSpec {
            prompt_len: 500,
            decode_len: 50,
            arrival: 0.0,
            prefix: Some(PrefixSpec { id, len: 384 }),
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::new();
        let v = views(&[0, 0, 0]);
        let picks: Vec<usize> = (0..7).map(|_| rr.route(&tagged(1), &v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_least_outstanding_with_index_ties() {
        let mut jsq = LeastOutstandingTokens::new();
        assert_eq!(jsq.route(&tagged(1), &views(&[300, 100, 200])), 1);
        assert_eq!(jsq.route(&tagged(1), &views(&[100, 100, 200])), 0, "tie → lowest index");
    }

    #[test]
    fn rendezvous_rank_is_a_permutation() {
        for key in [0u64, 1, 7, 0xDEAD_BEEF] {
            let rank = rendezvous_rank(key, 6);
            let mut sorted = rank.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..6).collect::<Vec<_>>(), "key {key}: {rank:?}");
        }
    }

    /// The allocation-free hot-path top-2 agrees with the full rank.
    #[test]
    fn rendezvous_top2_matches_the_rank() {
        for replicas in [2usize, 3, 4, 5, 8] {
            for k in 0..200u64 {
                let key = 0xABCD ^ (k * 6151);
                let rank = rendezvous_rank(key, replicas);
                assert_eq!(
                    rendezvous_top2(key, replicas),
                    (rank[0], rank[1]),
                    "key {key} replicas {replicas}"
                );
            }
        }
    }

    /// The HRW stability contract: growing R→R+1 re-homes only the
    /// templates whose top score now lands on the NEW replica — about
    /// 1/(R+1) of them — and never shuffles homes among the old replicas.
    #[test]
    fn rendezvous_growth_moves_only_a_fraction_to_the_new_replica() {
        let templates: Vec<u64> = (0..400u64).map(|k| 0x5EED + k * 7919).collect();
        let mut moved = 0;
        for &t in &templates {
            let before = rendezvous_rank(t, 4)[0];
            let after = rendezvous_rank(t, 5)[0];
            if after != before {
                moved += 1;
                assert_eq!(after, 4, "a moved template's new home IS the new replica");
            }
        }
        // E[moved] = 400/5 = 80; deterministic for these keys, wide net
        assert!(
            (40..=120).contains(&moved),
            "moved {moved}/400 templates (expect ~80 = 1/5)"
        );
        // coverage: every replica is home to a reasonable share
        let mut homes = [0usize; 4];
        for &t in &templates {
            homes[rendezvous_rank(t, 4)[0]] += 1;
        }
        assert!(homes.iter().all(|&h| h >= 50), "home spread {homes:?}");
    }

    /// The power-of-two shed triggers EXACTLY at the spill factor: at
    /// `home = F × second` the request stays home (strict inequality); one
    /// token more and it sheds to the second-ranked replica.
    #[test]
    fn spill_sheds_exactly_at_the_factor() {
        let spec = tagged(42);
        let rank = rendezvous_rank(42, 4);
        let (home, second) = (rank[0], rank[1]);
        let mut aff = PrefixAffinity::new(2.0);
        let mut v = views(&[0, 0, 0, 0]);
        v[second].outstanding_tokens = 100;
        v[home].outstanding_tokens = 200; // exactly F × second
        assert_eq!(aff.route(&spec, &v), home, "at the factor: stay home");
        v[home].outstanding_tokens = 201; // one past the factor
        assert_eq!(aff.route(&spec, &v), second, "past the factor: shed");
        // empty cluster: home stays home (0 > F×0 is false)
        assert_eq!(aff.route(&spec, &views(&[0, 0, 0, 0])), home);
    }

    #[test]
    fn affinity_routes_untagged_requests_by_jsq() {
        let mut aff = PrefixAffinity::default();
        let plain = RequestSpec { prompt_len: 100, decode_len: 10, arrival: 0.0, prefix: None };
        assert_eq!(aff.route(&plain, &views(&[500, 50, 300, 200])), 1);
    }

    #[test]
    fn default_spill_is_plain_power_of_two() {
        let spec = tagged(7);
        let rank = rendezvous_rank(7, 4);
        let (home, second) = (rank[0], rank[1]);
        let mut aff = PrefixAffinity::default();
        let mut v = views(&[0, 0, 0, 0]);
        v[home].outstanding_tokens = 101;
        v[second].outstanding_tokens = 100;
        assert_eq!(aff.route(&spec, &v), second, "strictly heavier home sheds");
        v[home].outstanding_tokens = 100;
        assert_eq!(aff.route(&spec, &v), home, "ties stay home");
    }

    #[test]
    fn router_kind_round_trips_and_builds() {
        for k in [RouterKind::RoundRobin, RouterKind::Jsq, RouterKind::Affinity] {
            assert_eq!(RouterKind::parse(k.name()), Some(k));
            assert_eq!(k.build(1.5).name(), k.name());
        }
        assert_eq!(RouterKind::parse("nope"), None);
    }
}
