//! Cluster-level request routing: which replica serves which request.
//!
//! The dispatch layer is its own optimization surface (DistServe's
//! goodput framing, SGLang's cache-aware load balancing): per-replica
//! scheduling can be stall-free and cache-aware, but if the *router*
//! sprays template traffic round-robin, every replica re-pays every
//! prefix and the cluster-wide hit rate collapses to 1/R of what the
//! workload offers. [`ClusterSim::run_routed`] dispatches arrivals one at
//! a time through a [`RoutePolicy`]:
//!
//! * [`RoundRobin`] — the baseline; reproduces the old static `g % R`
//!   partition byte-for-byte on an arrival-sorted workload.
//! * [`LeastOutstandingTokens`] — join-shortest-queue by each replica's
//!   cache-aware outstanding work ([`ReplicaView::outstanding_tokens`]).
//! * [`PrefixAffinity`] — cache-aware affinity routing. In its default
//!   **digest** mode each [`ReplicaView`] carries a
//!   [`ResidencyDigest`] — a bounded summary of the radix nodes actually
//!   READY on that replica, refreshed at dispatch barriers — and the
//!   router sends a tagged request to the replica whose digest covers the
//!   deepest prefix of the request's content path. Rendezvous hashing is
//!   only the cold-start tiebreak (no replica holds anything yet), and
//!   the load shed goes to the least-outstanding replica when the
//!   coverage home's backlog exceeds `spill_factor ×` that replica's —
//!   the shed request misses and registers there, replicating the hot
//!   prefix (emergent capacity for hot templates). The legacy
//!   **history** mode ([`PrefixAffinity::history`]) keeps the pure
//!   rendezvous home + power-of-two-choices spill to the second-ranked
//!   replica. Either way a shed request simply misses and admits
//!   full-price on the alternate, so shedding can never wedge a waiter
//!   chain.
//!
//! Rendezvous (highest-random-weight) hashing gives the stability the
//! prefix cache needs: adding a replica re-homes only ~1/(R+1) of the
//! templates (each moved template's new home IS the new replica), so a
//! scale-out does not cold-start every replica's prefix index the way
//! mod-R hashing would.
//!
//! [`ClusterSim::run_routed`]: super::cluster::ClusterSim::run_routed

use crate::coordinator::kv::{derived_path, ResidencyDigest};
use crate::util::mix64;
use crate::workload::RequestSpec;

/// Blocks of synthetic content path scored for a path-less `{id, len}`
/// template tag — generous enough to cover any digest entry a flat
/// registration can produce (64 blocks ≫ any registered template here).
const DERIVED_SCORE_BLOCKS: usize = 64;

/// What a routing policy sees of one replica at dispatch time.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaView {
    /// Cache-aware outstanding work: prefill + decode tokens the replica
    /// still has to compute for its dispatched, non-terminal requests
    /// (queued template traffic discounted by resident prefix coverage —
    /// see `PipelineRun::outstanding_tokens`).
    pub outstanding_tokens: usize,
    /// Bounded summary of the prefix-tree nodes READY on this replica
    /// (refreshed at dispatch barriers when the policy
    /// [`wants_digest`](RoutePolicy::wants_digest)); empty otherwise.
    pub digest: ResidencyDigest,
}

/// A pluggable dispatch policy: pick the replica for one arriving request
/// given a consistent snapshot of every replica's load.
pub trait RoutePolicy {
    fn route(&mut self, spec: &RequestSpec, views: &[ReplicaView]) -> usize;
    fn name(&self) -> &'static str;

    /// True when the policy reads [`ReplicaView::digest`] — the dispatch
    /// barrier only pays for digest refreshes if so, and load-oblivious
    /// policies (round-robin) stay bitwise-identical to their pre-digest
    /// behavior.
    fn wants_digest(&self) -> bool {
        false
    }
}

/// Arrival-order round-robin — the pre-router baseline.
#[derive(Clone, Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn route(&mut self, _spec: &RequestSpec, views: &[ReplicaView]) -> usize {
        let ri = self.next % views.len().max(1);
        self.next = self.next.wrapping_add(1);
        ri
    }

    fn name(&self) -> &'static str {
        "rr"
    }
}

/// Join-shortest-queue by outstanding work tokens (ties → lowest index).
#[derive(Clone, Copy, Debug, Default)]
pub struct LeastOutstandingTokens;

impl LeastOutstandingTokens {
    pub fn new() -> Self {
        Self
    }

    /// Lowest outstanding-token count, ties → lowest index. Shared by the
    /// routing policy and the disaggregation driver's decode-side handoff
    /// choice (which replica receives a finished prompt's KV).
    pub fn least(views: &[ReplicaView]) -> usize {
        views
            .iter()
            .enumerate()
            .min_by_key(|(i, v)| (v.outstanding_tokens, *i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

impl RoutePolicy for LeastOutstandingTokens {
    fn route(&mut self, _spec: &RequestSpec, views: &[ReplicaView]) -> usize {
        Self::least(views)
    }

    fn name(&self) -> &'static str {
        "jsq"
    }
}

/// Cache-aware prefix affinity with a bounded load shed.
///
/// **Digest mode** (default, [`PrefixAffinity::new`]): a tagged request
/// is scored against every replica's [`ResidencyDigest`] and goes to the
/// replica covering the deepest prefix of its content path (ties →
/// lowest index). A path-less `{id, len}` tag is scored through its
/// [`derived_path`] — the same synthetic chain the radix index lowers it
/// to, so flat tags route by actual residency too. When the coverage
/// home's outstanding work exceeds `spill_factor ×` the least-loaded
/// replica's, the request sheds to that least-loaded replica; its
/// full-price miss registers the template there, replicating the hot
/// prefix. With no coverage anywhere (cold start, or every digest
/// empty), routing falls back to the rendezvous top-2 rule below — which
/// also makes digest mode behave exactly like history mode when digests
/// are never populated.
///
/// **History mode** ([`PrefixAffinity::history`]): the template's
/// rendezvous home (top rank of [`rendezvous_rank`]) unless the home's
/// outstanding work exceeds `spill_factor ×` the second-ranked
/// replica's, in which case it sheds to the second. At the default
/// `spill_factor = 1.0` this is classic power-of-two-choices over the
/// template's top-2 replicas (strictly-greater comparison, ties stay
/// home); larger factors trade balance for stickiness.
///
/// Untagged requests fall through to join-shortest-queue over all
/// replicas in both modes.
#[derive(Clone, Copy, Debug)]
pub struct PrefixAffinity {
    /// Shed away from the coverage/rendezvous home when its outstanding
    /// work exceeds `spill_factor ×` the alternate's.
    pub spill_factor: f64,
    /// Score residency digests (default); false = pure rendezvous
    /// dispatch-history affinity.
    pub use_digest: bool,
}

impl PrefixAffinity {
    /// Default spill factor: plain power-of-two-choices over the top-2.
    pub const DEFAULT_SPILL: f64 = 1.0;

    /// Digest-scored affinity (reads [`ReplicaView::digest`]).
    pub fn new(spill_factor: f64) -> Self {
        assert!(spill_factor >= 0.0, "spill factor must be non-negative");
        PrefixAffinity { spill_factor, use_digest: true }
    }

    /// Legacy rendezvous-only affinity (ignores digests).
    pub fn history(spill_factor: f64) -> Self {
        PrefixAffinity { use_digest: false, ..Self::new(spill_factor) }
    }
}

impl Default for PrefixAffinity {
    fn default() -> Self {
        Self::new(Self::DEFAULT_SPILL)
    }
}

impl RoutePolicy for PrefixAffinity {
    fn route(&mut self, spec: &RequestSpec, views: &[ReplicaView]) -> usize {
        if views.len() <= 1 {
            return 0;
        }
        let Some(pfx) = spec.prefix.as_ref() else {
            return LeastOutstandingTokens::least(views);
        };
        if self.use_digest {
            let derived;
            let path: &[u64] = if pfx.path.is_empty() {
                derived = derived_path(pfx.id, DERIVED_SCORE_BLOCKS);
                &derived
            } else {
                &pfx.path
            };
            let mut home = 0usize;
            let mut best = 0u32;
            for (ri, v) in views.iter().enumerate() {
                let c = v.digest.coverage(path);
                if c > best {
                    best = c;
                    home = ri;
                }
            }
            if best > 0 {
                let least = LeastOutstandingTokens::least(views);
                let h = views[home].outstanding_tokens as f64;
                let l = views[least].outstanding_tokens as f64;
                return if h > self.spill_factor * l { least } else { home };
            }
            // no resident coverage anywhere: cold-start via rendezvous
        }
        let (home, second) = rendezvous_top2(pfx.id, views.len());
        let h = views[home].outstanding_tokens as f64;
        let s = views[second].outstanding_tokens as f64;
        if h > self.spill_factor * s {
            second
        } else {
            home
        }
    }

    fn name(&self) -> &'static str {
        if self.use_digest {
            "affinity"
        } else {
            "affinity-hist"
        }
    }

    fn wants_digest(&self) -> bool {
        self.use_digest
    }
}

const GOLD: u64 = 0x9E3779B97F4A7C15;

/// Rendezvous score of `key` on replica `ri`: one SplitMix64 step (the
/// golden-ratio increment plus [`mix64`] — the same mixer `util::Rng`
/// seeds with) over the key/replica combination.
fn score(key: u64, ri: usize) -> u64 {
    mix64((key ^ (ri as u64).wrapping_mul(GOLD)).wrapping_add(GOLD))
}

/// Replica indices ranked by rendezvous (highest-random-weight) score for
/// `key`, best first. Deterministic; ties broken by lowest index.
pub fn rendezvous_rank(key: u64, replicas: usize) -> Vec<usize> {
    let mut scored: Vec<(u64, usize)> = (0..replicas).map(|ri| (score(key, ri), ri)).collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().map(|(_, ri)| ri).collect()
}

/// The top-2 of [`rendezvous_rank`] without the allocation or the sort —
/// what the per-request routing hot path actually needs. Requires
/// `replicas >= 2`.
pub fn rendezvous_top2(key: u64, replicas: usize) -> (usize, usize) {
    debug_assert!(replicas >= 2, "top-2 needs at least two replicas");
    let mut best = (0u64, 0usize);
    let mut second = (0u64, 0usize);
    for ri in 0..replicas {
        let s = score(key, ri);
        // ascending index + strict > reproduces the rank's lowest-index
        // tie-break exactly
        if ri == 0 || s > best.0 {
            if ri > 0 {
                second = best;
            }
            best = (s, ri);
        } else if ri == 1 || s > second.0 {
            second = (s, ri);
        }
    }
    (best.1, second.1)
}

/// CLI-facing router selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RouterKind {
    RoundRobin,
    /// Join-shortest-queue by outstanding tokens.
    Jsq,
    /// Digest-scored prefix affinity with a least-loaded spill.
    Affinity,
    /// Legacy rendezvous (dispatch-history) affinity with the
    /// power-of-two spill — the pre-digest behavior, kept for A/B runs.
    AffinityHistory,
}

impl RouterKind {
    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "rr",
            RouterKind::Jsq => "jsq",
            RouterKind::Affinity => "affinity",
            RouterKind::AffinityHistory => "affinity-hist",
        }
    }

    /// Parse a CLI name (the inverse of [`name`](Self::name)).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rr" | "round-robin" => RouterKind::RoundRobin,
            "jsq" | "least-outstanding" => RouterKind::Jsq,
            "affinity" => RouterKind::Affinity,
            "affinity-hist" | "affinity-history" => RouterKind::AffinityHistory,
            _ => return None,
        })
    }

    /// Build the policy. `spill_factor` only shapes [`PrefixAffinity`].
    pub fn build(&self, spill_factor: f64) -> Box<dyn RoutePolicy> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin::new()),
            RouterKind::Jsq => Box::new(LeastOutstandingTokens::new()),
            RouterKind::Affinity => Box::new(PrefixAffinity::new(spill_factor)),
            RouterKind::AffinityHistory => Box::new(PrefixAffinity::history(spill_factor)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::PrefixSpec;

    fn views(outstanding: &[usize]) -> Vec<ReplicaView> {
        outstanding
            .iter()
            .map(|&t| ReplicaView { outstanding_tokens: t, ..Default::default() })
            .collect()
    }

    fn tagged(id: u64) -> RequestSpec {
        RequestSpec {
            prompt_len: 500,
            decode_len: 50,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(id, 384)),
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::new();
        let v = views(&[0, 0, 0]);
        let picks: Vec<usize> = (0..7).map(|_| rr.route(&tagged(1), &v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_least_outstanding_with_index_ties() {
        let mut jsq = LeastOutstandingTokens::new();
        assert_eq!(jsq.route(&tagged(1), &views(&[300, 100, 200])), 1);
        assert_eq!(jsq.route(&tagged(1), &views(&[100, 100, 200])), 0, "tie → lowest index");
    }

    #[test]
    fn rendezvous_rank_is_a_permutation() {
        for key in [0u64, 1, 7, 0xDEAD_BEEF] {
            let rank = rendezvous_rank(key, 6);
            let mut sorted = rank.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..6).collect::<Vec<_>>(), "key {key}: {rank:?}");
        }
    }

    /// The allocation-free hot-path top-2 agrees with the full rank.
    #[test]
    fn rendezvous_top2_matches_the_rank() {
        for replicas in [2usize, 3, 4, 5, 8] {
            for k in 0..200u64 {
                let key = 0xABCD ^ (k * 6151);
                let rank = rendezvous_rank(key, replicas);
                assert_eq!(
                    rendezvous_top2(key, replicas),
                    (rank[0], rank[1]),
                    "key {key} replicas {replicas}"
                );
            }
        }
    }

    /// The HRW stability contract: growing R→R+1 re-homes only the
    /// templates whose top score now lands on the NEW replica — about
    /// 1/(R+1) of them — and never shuffles homes among the old replicas.
    #[test]
    fn rendezvous_growth_moves_only_a_fraction_to_the_new_replica() {
        let templates: Vec<u64> = (0..400u64).map(|k| 0x5EED + k * 7919).collect();
        let mut moved = 0;
        for &t in &templates {
            let before = rendezvous_rank(t, 4)[0];
            let after = rendezvous_rank(t, 5)[0];
            if after != before {
                moved += 1;
                assert_eq!(after, 4, "a moved template's new home IS the new replica");
            }
        }
        // E[moved] = 400/5 = 80; deterministic for these keys, wide net
        assert!(
            (40..=120).contains(&moved),
            "moved {moved}/400 templates (expect ~80 = 1/5)"
        );
        // coverage: every replica is home to a reasonable share
        let mut homes = [0usize; 4];
        for &t in &templates {
            homes[rendezvous_rank(t, 4)[0]] += 1;
        }
        assert!(homes.iter().all(|&h| h >= 50), "home spread {homes:?}");
    }

    /// The power-of-two shed triggers EXACTLY at the spill factor: at
    /// `home = F × second` the request stays home (strict inequality); one
    /// token more and it sheds to the second-ranked replica.
    #[test]
    fn spill_sheds_exactly_at_the_factor() {
        let spec = tagged(42);
        let rank = rendezvous_rank(42, 4);
        let (home, second) = (rank[0], rank[1]);
        let mut aff = PrefixAffinity::new(2.0);
        let mut v = views(&[0, 0, 0, 0]);
        v[second].outstanding_tokens = 100;
        v[home].outstanding_tokens = 200; // exactly F × second
        assert_eq!(aff.route(&spec, &v), home, "at the factor: stay home");
        v[home].outstanding_tokens = 201; // one past the factor
        assert_eq!(aff.route(&spec, &v), second, "past the factor: shed");
        // empty cluster: home stays home (0 > F×0 is false)
        assert_eq!(aff.route(&spec, &views(&[0, 0, 0, 0])), home);
    }

    #[test]
    fn affinity_routes_untagged_requests_by_jsq() {
        let mut aff = PrefixAffinity::default();
        let plain = RequestSpec { prompt_len: 100, decode_len: 10, arrival: 0.0, prefix: None };
        assert_eq!(aff.route(&plain, &views(&[500, 50, 300, 200])), 1);
    }

    #[test]
    fn default_spill_is_plain_power_of_two() {
        let spec = tagged(7);
        let rank = rendezvous_rank(7, 4);
        let (home, second) = (rank[0], rank[1]);
        let mut aff = PrefixAffinity::default();
        let mut v = views(&[0, 0, 0, 0]);
        v[home].outstanding_tokens = 101;
        v[second].outstanding_tokens = 100;
        assert_eq!(aff.route(&spec, &v), second, "strictly heavier home sheds");
        v[home].outstanding_tokens = 100;
        assert_eq!(aff.route(&spec, &v), home, "ties stay home");
    }

    #[test]
    fn router_kind_round_trips_and_builds() {
        for k in [
            RouterKind::RoundRobin,
            RouterKind::Jsq,
            RouterKind::Affinity,
            RouterKind::AffinityHistory,
        ] {
            assert_eq!(RouterKind::parse(k.name()), Some(k));
            assert_eq!(k.build(1.5).name(), k.name());
        }
        assert_eq!(RouterKind::parse("affinity-history"), Some(RouterKind::AffinityHistory));
        assert_eq!(RouterKind::parse("nope"), None);
        // only digest-mode affinity asks the barrier for digests
        assert!(RouterKind::Affinity.build(1.0).wants_digest());
        assert!(!RouterKind::AffinityHistory.build(1.0).wants_digest());
        assert!(!RouterKind::RoundRobin.build(1.0).wants_digest());
        assert!(!RouterKind::Jsq.build(1.0).wants_digest());
    }

    /// Digest mode routes to the replica whose digest covers the DEEPEST
    /// prefix of the request's content path — not the rendezvous home,
    /// not the least-loaded.
    #[test]
    fn digest_coverage_beats_rendezvous_and_load() {
        let path = vec![0xA1u64, 0xA2, 0xA3, 0xA4];
        let spec = RequestSpec {
            prompt_len: 200,
            decode_len: 20,
            arrival: 0.0,
            prefix: Some(PrefixSpec::with_path(77, 128, path)),
        };
        let mut aff = PrefixAffinity::new(4.0);
        let mut v = views(&[10, 30, 10, 10]);
        // replica 1 holds 3 blocks of the path ready, replica 2 only 1
        v[1].digest = ResidencyDigest::from_entries(&[(0xA3, 96)]);
        v[2].digest = ResidencyDigest::from_entries(&[(0xA1, 32)]);
        assert_eq!(aff.route(&spec, &v), 1, "deepest coverage wins");
        // an entry NOT on the path certifies nothing
        v[3].digest = ResidencyDigest::from_entries(&[(0xFF, 128)]);
        assert_eq!(aff.route(&spec, &v), 1);
    }

    /// The digest-mode shed: past `spill × least`, the request goes to
    /// the least-outstanding replica (replicating the hot prefix there).
    #[test]
    fn digest_spill_sheds_to_least_outstanding() {
        let path = vec![0xB1u64, 0xB2];
        let spec = RequestSpec {
            prompt_len: 100,
            decode_len: 10,
            arrival: 0.0,
            prefix: Some(PrefixSpec::with_path(9, 64, path)),
        };
        let mut aff = PrefixAffinity::new(2.0);
        let mut v = views(&[200, 100, 401]);
        v[2].digest = ResidencyDigest::from_entries(&[(0xB2, 64)]);
        // home=2 (only coverage), least=1: 401 > 2.0 × 100 → shed to 1
        assert_eq!(aff.route(&spec, &v), 1, "overloaded home sheds to least");
        v[2].outstanding_tokens = 200; // at the factor: stay home
        assert_eq!(aff.route(&spec, &v), 2);
    }

    /// A path-less `{id, len}` tag scores through its derived path — the
    /// same synthetic chain the radix index lowers it to — so flat tags
    /// still route by residency.
    #[test]
    fn flat_tags_score_digests_via_the_derived_path() {
        let spec = tagged(123);
        let chain = derived_path(123, 4);
        let mut aff = PrefixAffinity::default();
        let mut v = views(&[50, 50, 50, 50]);
        v[0].digest = ResidencyDigest::from_entries(&[(chain[3], 128)]);
        assert_eq!(aff.route(&spec, &v), 0, "resident flat template attracts its traffic");
        // history mode ignores the digest and uses the rendezvous home
        let mut hist = PrefixAffinity::history(1.0);
        let (home, _) = rendezvous_top2(123, 4);
        assert_eq!(hist.route(&spec, &v), home);
    }

    /// With every digest empty, digest mode degrades to exactly the
    /// rendezvous top-2 behavior (cold-start tiebreak).
    #[test]
    fn empty_digests_fall_back_to_rendezvous() {
        let spec = tagged(42);
        let v = views(&[5, 10, 15, 20]);
        let mut digest = PrefixAffinity::new(1.5);
        let mut hist = PrefixAffinity::history(1.5);
        assert_eq!(digest.route(&spec, &v), hist.route(&spec, &v));
    }
}
