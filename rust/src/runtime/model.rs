//! `ModelRuntime`: the compiled model + weights + KV-cache state.
//!
//! Loads HLO **text** artifacts (`HloModuleProto::from_text_file` — see
//! DESIGN.md §2 for why text, not serialized protos), compiles them once on
//! the PJRT CPU client, and executes steps with the KV cache threaded
//! through as a functional input/output (the multi-output jax functions
//! come back as one tuple literal which we decompose host-side).

use crate::util::error::Result;
use crate::{bail, err};
use std::collections::HashMap;
use std::path::Path;
use xla::FromRawBytes;

use super::manifest::Manifest;

/// Result of a prefill-chunk step.
pub struct PrefillOut {
    /// Logits of the last *real* (unpadded) chunk token, [vocab].
    pub logits: Vec<f32>,
}

/// Result of a decode step: per-lane logits.
pub struct DecodeOut {
    /// [lanes][vocab]
    pub logits: Vec<Vec<f32>>,
}

pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Weights in manifest positional order.
    weights: Vec<xla::Literal>,
    /// Functional KV state, [layers, slots, max_len, heads, head_dim] f32.
    k_cache: xla::Literal,
    v_cache: xla::Literal,
    /// Steps executed (observability / bench counters).
    pub steps: usize,
}

fn i32_lit(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

impl ModelRuntime {
    /// Load manifest + weights and compile every artifact.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu client: {e:?}"))?;

        // weights.npz → positional literal list
        let named: Vec<(String, xla::Literal)> =
            xla::Literal::read_npz(&manifest.weights_file, &())
                .map_err(|e| err!("reading {:?}: {e:?}", manifest.weights_file))?;
        let mut by_name: HashMap<String, xla::Literal> = named.into_iter().collect();
        let mut weights = Vec::with_capacity(manifest.param_order.len());
        for name in &manifest.param_order {
            let lit = by_name
                .remove(name)
                .ok_or_else(|| err!("weights.npz missing parameter {name}"))?;
            weights.push(lit);
        }

        let mut executables = HashMap::new();
        for art in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(&art.file)
                .map_err(|e| err!("parsing {:?}: {e:?}", art.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| err!("compiling {}: {e:?}", art.name))?;
            executables.insert(art.name.clone(), exe);
        }

        let m = &manifest.model;
        let kv_elems = m.layers * m.kv_slots * m.max_len * m.hidden;
        let zeros = vec![0f32; kv_elems];
        let dims: Vec<i64> = vec![
            m.layers as i64,
            m.kv_slots as i64,
            m.max_len as i64,
            m.heads as i64,
            (m.hidden / m.heads) as i64,
        ];
        let k_cache = xla::Literal::vec1(&zeros)
            .reshape(&dims)
            .map_err(|e| err!("kv reshape: {e:?}"))?;
        let v_cache = xla::Literal::vec1(&zeros)
            .reshape(&dims)
            .map_err(|e| err!("kv reshape: {e:?}"))?;

        Ok(ModelRuntime { manifest, client, executables, weights, k_cache, v_cache, steps: 0 })
    }

    /// Clear the KV cache (fresh serving session).
    pub fn reset_kv(&mut self) -> Result<()> {
        let m = &self.manifest.model;
        let kv_elems = m.layers * m.kv_slots * m.max_len * m.hidden;
        let zeros = vec![0f32; kv_elems];
        let dims: Vec<i64> = vec![
            m.layers as i64,
            m.kv_slots as i64,
            m.max_len as i64,
            m.heads as i64,
            (m.hidden / m.heads) as i64,
        ];
        self.k_cache =
            xla::Literal::vec1(&zeros).reshape(&dims).map_err(|e| err!("{e:?}"))?;
        self.v_cache =
            xla::Literal::vec1(&zeros).reshape(&dims).map_err(|e| err!("{e:?}"))?;
        Ok(())
    }

    fn run(&mut self, name: &str, extra: Vec<xla::Literal>, n_extra_outputs: usize)
        -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| err!("no artifact named {name}"))?;
        // inputs: params..., k, v, step inputs...
        let mut inputs: Vec<&xla::Literal> = self.weights.iter().collect();
        inputs.push(&self.k_cache);
        inputs.push(&self.v_cache);
        for lit in &extra {
            inputs.push(lit);
        }
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| err!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetch {name}: {e:?}"))?;
        let mut parts = tuple.to_tuple().map_err(|e| err!("untuple {name}: {e:?}"))?;
        if parts.len() != n_extra_outputs + 2 {
            bail!("{name}: expected {} outputs, got {}", n_extra_outputs + 2, parts.len());
        }
        // trailing two outputs are the updated KV state
        self.v_cache = parts.pop().unwrap();
        self.k_cache = parts.pop().unwrap();
        self.steps += 1;
        Ok(parts)
    }

    fn logits_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| err!("logits: {e:?}"))
    }

    /// One chunked-prefill iteration: `tokens` (≤ bucket size) of the
    /// request in `slot`, starting at prompt offset `start`. Returns the
    /// logits of the last real token (meaningful only on the final chunk).
    pub fn prefill_chunk(&mut self, tokens: &[i32], slot: usize, start: usize) -> Result<PrefillOut> {
        let len = tokens.len();
        let art = self
            .manifest
            .prefill_bucket(len)
            .ok_or_else(|| err!("no prefill bucket fits {len} tokens"))?;
        let bucket = art.chunk.unwrap();
        let name = art.name.clone();
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);
        let extra = vec![
            i32_lit(&padded),
            scalar_i32(slot as i32),
            scalar_i32(start as i32),
            scalar_i32(len as i32),
        ];
        let parts = self.run(&name, extra, 1)?;
        Ok(PrefillOut { logits: Self::logits_vec(&parts[0])? })
    }

    /// One decode-only iteration over up to `decode_slots` lanes.
    /// Each lane: (token, slot, position). Missing lanes are padded to the
    /// scratch slot. Returns per-real-lane logits.
    pub fn decode(&mut self, lanes: &[(i32, usize, usize)]) -> Result<DecodeOut> {
        let art = self
            .manifest
            .decode_artifact()
            .ok_or_else(|| err!("no decode artifact"))?;
        let d = art.dslots.unwrap();
        let name = art.name.clone();
        if lanes.len() > d {
            bail!("{} decode lanes exceed artifact capacity {d}", lanes.len());
        }
        let scratch = self.manifest.model.scratch_slot() as i32;
        let mut toks = vec![0i32; d];
        let mut slots = vec![scratch; d];
        let mut pos = vec![0i32; d];
        for (i, &(t, s, p)) in lanes.iter().enumerate() {
            toks[i] = t;
            slots[i] = s as i32;
            pos[i] = p as i32;
        }
        let extra = vec![i32_lit(&toks), i32_lit(&slots), i32_lit(&pos)];
        let parts = self.run(&name, extra, 1)?;
        let flat = Self::logits_vec(&parts[0])?;
        let vocab = self.manifest.model.vocab;
        Ok(DecodeOut {
            logits: (0..lanes.len()).map(|i| flat[i * vocab..(i + 1) * vocab].to_vec()).collect(),
        })
    }

    /// One decode-maximal iteration: ONE prefill chunk plus piggybacked
    /// decode lanes, fused through the hybrid artifact (§4.3).
    pub fn hybrid(
        &mut self,
        p_tokens: &[i32],
        p_slot: usize,
        p_start: usize,
        lanes: &[(i32, usize, usize)],
    ) -> Result<(PrefillOut, DecodeOut)> {
        let len = p_tokens.len();
        let art = self
            .manifest
            .hybrid_bucket(len)
            .ok_or_else(|| err!("no hybrid bucket fits {len} tokens"))?;
        let bucket = art.chunk.unwrap();
        let d = art.dslots.unwrap();
        let name = art.name.clone();
        if lanes.len() > d {
            bail!("{} decode lanes exceed hybrid capacity {d}", lanes.len());
        }
        let mut padded = p_tokens.to_vec();
        padded.resize(bucket, 0);
        let scratch = self.manifest.model.scratch_slot() as i32;
        let mut toks = vec![0i32; d];
        let mut slots = vec![scratch; d];
        let mut pos = vec![0i32; d];
        for (i, &(t, s, p)) in lanes.iter().enumerate() {
            toks[i] = t;
            slots[i] = s as i32;
            pos[i] = p as i32;
        }
        let extra = vec![
            i32_lit(&padded),
            scalar_i32(p_slot as i32),
            scalar_i32(p_start as i32),
            scalar_i32(len as i32),
            i32_lit(&toks),
            i32_lit(&slots),
            i32_lit(&pos),
        ];
        let parts = self.run(&name, extra, 2)?;
        let p_logits = Self::logits_vec(&parts[0])?;
        let flat = Self::logits_vec(&parts[1])?;
        let vocab = self.manifest.model.vocab;
        Ok((
            PrefillOut { logits: p_logits },
            DecodeOut {
                logits: (0..lanes.len())
                    .map(|i| flat[i * vocab..(i + 1) * vocab].to_vec())
                    .collect(),
            },
        ))
    }

    /// Convenience: fully prefill a prompt into `slot` with chunked
    /// prefills of the largest available bucket; returns final logits.
    pub fn prefill_all(&mut self, prompt: &[i32], slot: usize) -> Result<Vec<f32>> {
        let chunk = self.manifest.max_chunk();
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let mut out = None;
        let mut start = 0;
        while start < prompt.len() {
            let end = (start + chunk).min(prompt.len());
            let res = self.prefill_chunk(&prompt[start..end], slot, start)?;
            out = Some(res.logits);
            start = end;
        }
        Ok(out.unwrap())
    }

    /// Greedy generation for quickstart/demo: chunked prefill + decode-only
    /// loop on one slot.
    pub fn generate_greedy(&mut self, prompt: &[i32], slot: usize, n_tokens: usize) -> Result<Vec<i32>> {
        let logits = self.prefill_all(prompt, slot)?;
        let mut out = vec![super::sampler::argmax(&logits) as i32];
        let mut pos = prompt.len();
        while out.len() < n_tokens {
            let last = *out.last().unwrap();
            let res = self.decode(&[(last, slot, pos)])?;
            out.push(super::sampler::argmax(&res.logits[0]) as i32);
            pos += 1;
        }
        Ok(out)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
