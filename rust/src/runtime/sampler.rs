//! Sampling over returned logits (rust-side; the AOT graphs return raw
//! logits so the serving policy stays in the coordinator).

/// Greedy: index of the maximum logit.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

/// Deterministic top-k "sampling": pick the `rank`-th largest logit
/// (rank 0 = argmax). Used by tests to exercise non-greedy paths without a
/// stochastic dependency.
pub fn top_k_deterministic(logits: &[f32], rank: usize) -> usize {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
    idx[rank.min(idx.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn top_k_ranks() {
        let l = [0.5, 2.0, 1.0];
        assert_eq!(top_k_deterministic(&l, 0), 1);
        assert_eq!(top_k_deterministic(&l, 1), 2);
        assert_eq!(top_k_deterministic(&l, 2), 0);
        assert_eq!(top_k_deterministic(&l, 99), 0); // clamped
    }
}
