//! `RealExecutor`: the engine's [`Executor`] backed by the PJRT model.
//!
//! The engine's batches carry only offsets/lengths; this adapter owns the
//! actual token ids — prompts in, generated tokens out — and maps work
//! items onto the AOT shape buckets:
//!
//! * `PrefillChunk` → `prefill_c{N}` (padded to the bucket),
//! * `Decode` lanes → `decode_d{D}` in groups of D,
//! * one chunk + lanes → `hybrid_c{N}_d{D}` — the decode-maximal step.

use crate::util::error::{Error, Result};
use std::time::Instant;

use super::model::ModelRuntime;
use super::sampler::argmax;
use crate::coordinator::{Batch, Executor, RequestPool, StepOutcome};

/// Per-request generation state, indexed by the engine's request id.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generated token ids (first produced by the final prefill chunk).
    pub generated: Vec<i32>,
}

impl GenRequest {
    pub fn new(prompt: Vec<i32>) -> Self {
        GenRequest { prompt, generated: Vec::new() }
    }

    /// The token a decode step should feed (the last generated one).
    fn last_token(&self) -> i32 {
        *self.generated.last().expect("decode before first token")
    }

    /// Position of the next token to write into the KV cache.
    fn next_pos(&self) -> usize {
        self.prompt.len() + self.generated.len() - 1
    }
}

pub struct RealExecutor {
    pub model: ModelRuntime,
    pub requests: Vec<GenRequest>,
    /// Execution error, if any (the Executor trait is infallible; errors
    /// are surfaced after the run).
    pub error: Option<Error>,
}

impl RealExecutor {
    pub fn new(model: ModelRuntime, requests: Vec<GenRequest>) -> Self {
        RealExecutor { model, requests, error: None }
    }

    pub fn into_requests(self) -> Vec<GenRequest> {
        self.requests
    }

    /// Run decode lanes through the decode artifact in capacity-sized
    /// groups, collecting per-request logits.
    fn decode_groups(
        &mut self,
        lanes: &[(usize, (i32, usize, usize))],
        lane_logits: &mut Vec<(usize, Vec<f32>)>,
    ) -> Result<()> {
        let d_cap = self.model.manifest.model.decode_slots;
        for group in lanes.chunks(d_cap.max(1)) {
            let ls: Vec<_> = group.iter().map(|&(_, l)| l).collect();
            let out = self.model.decode(&ls)?;
            for (k, &(id, _)) in group.iter().enumerate() {
                lane_logits.push((id, out.logits[k].clone()));
            }
        }
        Ok(())
    }

    fn exec(&mut self, batch: &Batch, pool: &RequestPool) -> Result<()> {
        let prefill: Vec<(usize, usize, usize)> = batch.prefill_items().collect();
        let decode_ids: Vec<usize> = batch.decode_items().collect();
        let d_cap = self.model.manifest.model.decode_slots;

        // Build decode lanes: (token, slot, position) per decoding request.
        let lanes: Vec<(usize, (i32, usize, usize))> = decode_ids
            .iter()
            .map(|&id| {
                let g = &self.requests[id];
                let slot = pool.get(id).slot().expect("decode without slot");
                (id, (g.last_token(), slot, g.next_pos()))
            })
            .collect();

        let mut lane_logits: Vec<(usize, Vec<f32>)> = Vec::new();

        match prefill.as_slice() {
            [] => {
                // decode-only iteration(s), in artifact-sized groups
                self.decode_groups(&lanes, &mut lane_logits)?;
            }
            [(req, start, len)] if !lanes.is_empty() => {
                // decode-maximal: one chunk + up to D piggybacked lanes. A
                // chunk larger than the biggest hybrid bucket (Orca-best
                // submits whole prompts) is split: the lanes ride the first
                // sub-chunk, the rest prefills plain.
                let (head, tail) = lanes.split_at(lanes.len().min(d_cap));
                let slot = pool.get(*req).slot().expect("prefill without slot");
                let max_hb = self
                    .model
                    .manifest
                    .artifacts
                    .iter()
                    .filter(|a| a.kind == super::manifest::ArtifactKind::Hybrid)
                    .filter_map(|a| a.chunk)
                    .max()
                    .unwrap_or(0);
                let first = (*len).min(max_hb.max(1));
                let toks = self.requests[*req].prompt[*start..*start + first].to_vec();
                let ls: Vec<_> = head.iter().map(|&(_, l)| l).collect();
                let (p_out, d_out) = self.model.hybrid(&toks, slot, *start, &ls)?;
                for (k, &(id, _)) in head.iter().enumerate() {
                    lane_logits.push((id, d_out.logits[k].clone()));
                }
                // overflow lanes (beyond the artifact's D) go decode-only
                self.decode_groups(tail, &mut lane_logits)?;
                let last = if first < *len {
                    self.prefill_range(*req, slot, *start + first, *len - first)?
                } else {
                    p_out.logits
                };
                self.finish_prefill(*req, pool, *start, *len, last)?;
            }
            chunks => {
                // several prefill chunks (baseline mode, or a hybrid-
                // scheduler batch with multiple concurrent prefills): any
                // decode lanes run decode-only first, then each chunk
                // prefills plain
                self.decode_groups(&lanes, &mut lane_logits)?;
                for &(req, start, len) in chunks {
                    let slot = pool.get(req).slot().expect("prefill without slot");
                    let last = self.prefill_range(req, slot, start, len)?;
                    self.finish_prefill(req, pool, start, len, last)?;
                }
            }
        }

        // sample decode outputs
        for (id, logits) in lane_logits {
            let tok = argmax(&logits) as i32;
            self.requests[id].generated.push(tok);
        }
        Ok(())
    }

    /// Prefill `[start, start+len)` of a request's prompt through the
    /// artifact buckets; returns the logits of the final sub-chunk.
    fn prefill_range(&mut self, req: usize, slot: usize, start: usize, len: usize) -> Result<Vec<f32>> {
        let max_chunk = self.model.manifest.max_chunk();
        let mut s = start;
        let mut last = None;
        while s < start + len {
            let e = (s + max_chunk).min(start + len);
            let toks = self.requests[req].prompt[s..e].to_vec();
            let out = self.model.prefill_chunk(&toks, slot, s)?;
            last = Some(out.logits);
            s = e;
        }
        Ok(last.expect("empty prefill range"))
    }

    /// If this chunk completes the prompt, its logits yield the first
    /// output token.
    fn finish_prefill(
        &mut self,
        req: usize,
        pool: &RequestPool,
        start: usize,
        len: usize,
        logits: Vec<f32>,
    ) -> Result<()> {
        let prompt_len = pool.get(req).spec.prompt_len;
        if start + len == prompt_len {
            let tok = argmax(&logits) as i32;
            self.requests[req].generated.push(tok);
        }
        Ok(())
    }
}

impl Executor for RealExecutor {
    fn execute(&mut self, batch: &Batch, pool: &RequestPool) -> StepOutcome {
        let t0 = Instant::now();
        if self.error.is_none() {
            if let Err(e) = self.exec(batch, pool) {
                self.error = Some(e);
            }
        }
        StepOutcome { elapsed: t0.elapsed().as_secs_f64(), prefill_alone: None, breakdown: None }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}
