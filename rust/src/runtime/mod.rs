//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! `weights.npz`, `manifest.txt`) produced by `python/compile/aot.py` and
//! serves the tiny model for real on the PJRT CPU client. Python is never
//! on this path.
//!
//! * [`manifest`] — artifact manifest parsing (always built).
//! * [`sampler`] — greedy / top-k sampling over returned logits (always
//!   built).
//! * `model` — `ModelRuntime`: compiled executables + weights + the
//!   functional KV-cache state, exposing the three step functions the
//!   scheduler composes (prefill chunk / decode / decode-maximal hybrid).
//! * `executor` — `RealExecutor`: adapts `ModelRuntime` to the engine's
//!   [`crate::coordinator::Executor`] trait, carrying real token ids.
//!
//! `model`/`executor` depend on the external `xla` PJRT bindings, which
//! the offline build environment does not ship — they are gated behind the
//! `pjrt` cargo feature (see rust/Cargo.toml for how to enable it with a
//! vendored `xla` crate). Everything else in the workspace, including the
//! cost-model serving path, builds and runs without it.

#[cfg(feature = "pjrt")]
pub mod executor;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod model;
pub mod sampler;

#[cfg(feature = "pjrt")]
pub use executor::{GenRequest, RealExecutor};
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest, ModelInfo};
#[cfg(feature = "pjrt")]
pub use model::ModelRuntime;
pub use sampler::{argmax, top_k_deterministic};
