//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! `weights.npz`, `manifest.txt`) produced by `python/compile/aot.py` and
//! serves the tiny model for real on the PJRT CPU client. Python is never
//! on this path.
//!
//! * [`manifest`] — artifact manifest parsing.
//! * [`model`] — `ModelRuntime`: compiled executables + weights + the
//!   functional KV-cache state, exposing the three step functions the
//!   scheduler composes (prefill chunk / decode / decode-maximal hybrid).
//! * [`executor`] — `RealExecutor`: adapts `ModelRuntime` to the engine's
//!   [`crate::coordinator::Executor`] trait, carrying real token ids.
//! * [`sampler`] — greedy / top-k sampling over returned logits.

pub mod executor;
pub mod manifest;
pub mod model;
pub mod sampler;

pub use executor::{GenRequest, RealExecutor};
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest, ModelInfo};
pub use model::ModelRuntime;
pub use sampler::{argmax, top_k_deterministic};
