//! Parse `artifacts/manifest.txt` (format written by python/compile/aot.py).
//!
//! ```text
//! format 1
//! model tiny vocab=256 hidden=128 heads=4 layers=2 ffn=512 max_len=256 kv_slots=8 decode_slots=4
//! weights weights.npz embed l0.ln1 ...
//! artifact name=prefill_c16 kind=prefill chunk=16 file=prefill_c16.hlo.txt
//! ```

use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Prefill,
    Decode,
    Hybrid,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    /// Chunk size for prefill/hybrid artifacts.
    pub chunk: Option<usize>,
    /// Decode lanes for decode/hybrid artifacts.
    pub dslots: Option<usize>,
    pub file: PathBuf,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub vocab: usize,
    pub hidden: usize,
    pub heads: usize,
    pub layers: usize,
    pub ffn: usize,
    pub max_len: usize,
    pub kv_slots: usize,
    pub decode_slots: usize,
}

impl ModelInfo {
    /// The last KV row is scratch for padded decode lanes.
    pub fn scratch_slot(&self) -> usize {
        self.kv_slots - 1
    }

    pub fn usable_slots(&self) -> usize {
        self.kv_slots - 1
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub weights_file: PathBuf,
    /// Parameter names in positional order (load-bearing).
    pub param_order: Vec<String>,
    pub artifacts: Vec<ArtifactEntry>,
}

fn kv_pairs(parts: &[&str]) -> HashMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn get_usize(map: &HashMap<String, String>, key: &str) -> Result<usize> {
    map.get(key)
        .ok_or_else(|| err!("missing key {key}"))?
        .parse()
        .with_context(|| format!("bad value for {key}"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().ok_or_else(|| err!("empty manifest"))?;
        if header.trim() != "format 1" {
            bail!("unsupported manifest format: {header:?}");
        }

        let mut model = None;
        let mut weights_file = None;
        let mut param_order = Vec::new();
        let mut artifacts = Vec::new();

        for line in lines {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.first().copied() {
                Some("model") => {
                    let kv = kv_pairs(&parts[2..]);
                    model = Some(ModelInfo {
                        vocab: get_usize(&kv, "vocab")?,
                        hidden: get_usize(&kv, "hidden")?,
                        heads: get_usize(&kv, "heads")?,
                        layers: get_usize(&kv, "layers")?,
                        ffn: get_usize(&kv, "ffn")?,
                        max_len: get_usize(&kv, "max_len")?,
                        kv_slots: get_usize(&kv, "kv_slots")?,
                        decode_slots: get_usize(&kv, "decode_slots")?,
                    });
                }
                Some("weights") => {
                    weights_file = Some(dir.join(parts.get(1).ok_or_else(|| err!("weights line missing file"))?));
                    param_order = parts[2..].iter().map(|s| s.to_string()).collect();
                }
                Some("artifact") => {
                    let kv = kv_pairs(&parts[1..]);
                    let kind = match kv.get("kind").map(String::as_str) {
                        Some("prefill") => ArtifactKind::Prefill,
                        Some("decode") => ArtifactKind::Decode,
                        Some("hybrid") => ArtifactKind::Hybrid,
                        other => bail!("unknown artifact kind {other:?}"),
                    };
                    artifacts.push(ArtifactEntry {
                        name: kv.get("name").cloned().ok_or_else(|| err!("artifact missing name"))?,
                        kind,
                        chunk: kv.get("chunk").map(|c| c.parse()).transpose()?,
                        dslots: kv.get("dslots").map(|c| c.parse()).transpose()?,
                        file: dir.join(kv.get("file").ok_or_else(|| err!("artifact missing file"))?),
                    });
                }
                _ => bail!("unrecognized manifest line: {line:?}"),
            }
        }

        let model = model.ok_or_else(|| err!("manifest has no model line"))?;
        let weights_file = weights_file.ok_or_else(|| err!("manifest has no weights line"))?;
        if param_order.is_empty() {
            bail!("weights line lists no parameters");
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest { dir: dir.to_path_buf(), model, weights_file, param_order, artifacts })
    }

    /// Smallest prefill chunk bucket that fits `len` tokens, if any.
    pub fn prefill_bucket(&self, len: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Prefill && a.chunk.unwrap_or(0) >= len)
            .min_by_key(|a| a.chunk.unwrap())
    }

    /// Hybrid artifact for `len` chunk tokens (smallest bucket that fits).
    pub fn hybrid_bucket(&self, len: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Hybrid && a.chunk.unwrap_or(0) >= len)
            .min_by_key(|a| a.chunk.unwrap())
    }

    pub fn decode_artifact(&self) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.kind == ArtifactKind::Decode)
    }

    /// Largest prefill chunk available (the scheduler's chunk size).
    pub fn max_chunk(&self) -> usize {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Prefill)
            .filter_map(|a| a.chunk)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
format 1
model tiny vocab=256 hidden=128 heads=4 layers=2 ffn=512 max_len=256 kv_slots=8 decode_slots=4
weights weights.npz embed l0.ln1 l0.wqkv lnf
artifact name=prefill_c16 kind=prefill chunk=16 file=prefill_c16.hlo.txt
artifact name=prefill_c32 kind=prefill chunk=32 file=prefill_c32.hlo.txt
artifact name=decode_d4 kind=decode dslots=4 file=decode_d4.hlo.txt
artifact name=hybrid_c16_d4 kind=hybrid chunk=16 dslots=4 file=hybrid_c16_d4.hlo.txt
artifact name=hybrid_c32_d4 kind=hybrid chunk=32 dslots=4 file=hybrid_c32_d4.hlo.txt
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.model.vocab, 256);
        assert_eq!(m.model.scratch_slot(), 7);
        assert_eq!(m.param_order.len(), 4);
        assert_eq!(m.artifacts.len(), 5);
        assert_eq!(m.max_chunk(), 32);
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.prefill_bucket(10).unwrap().chunk, Some(16));
        assert_eq!(m.prefill_bucket(16).unwrap().chunk, Some(16));
        assert_eq!(m.prefill_bucket(17).unwrap().chunk, Some(32));
        assert!(m.prefill_bucket(33).is_none());
        assert_eq!(m.hybrid_bucket(20).unwrap().name, "hybrid_c32_d4");
        assert_eq!(m.decode_artifact().unwrap().dslots, Some(4));
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(Path::new("/"), "format 2\n").is_err());
        assert!(Manifest::parse(Path::new("/"), "").is_err());
        assert!(Manifest::parse(Path::new("/"), "format 1\njunk line\n").is_err());
    }
}
