//! Scheduler policy configuration.

/// Which batching policy the engine runs (§5's comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// FasterTransformer-style request-level scheduling: prefill-only then
    /// decode-only batches, next batch only when the whole batch completes.
    RequestLevel,
    /// Orca iteration-level scheduling, best case (§5.2): one *full* prefill
    /// may overlap running decodes each iteration.
    OrcaBest,
    /// Orca worst case: all requests enter/leave together — degenerates to
    /// prefill-only/decode-only batches.
    OrcaWorst,
    /// SARATHI: chunked-prefills + decode-maximal batching.
    Sarathi,
}

impl SchedulerKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::RequestLevel => "request-level",
            SchedulerKind::OrcaBest => "orca-best",
            SchedulerKind::OrcaWorst => "orca-worst",
            SchedulerKind::Sarathi => "sarathi",
        }
    }
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    pub kind: SchedulerKind,
    /// SARATHI chunk size C (tokens). Ignored by other policies.
    pub chunk_size: usize,
    /// Tile size the fused token count is aligned to (§4.4: the prefill
    /// chunk shrinks so chunk + piggybacked decodes is a tile multiple).
    pub tile_align: usize,
    /// Maximum batch size B (from the §4.3.1 capacity formula).
    pub max_batch: usize,
}

impl SchedulerConfig {
    pub fn sarathi(chunk_size: usize, max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::Sarathi, chunk_size, tile_align: 128, max_batch }
    }

    pub fn baseline(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::RequestLevel, chunk_size: 0, tile_align: 128, max_batch }
    }

    pub fn orca_best(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::OrcaBest, chunk_size: 0, tile_align: 128, max_batch }
    }

    pub fn orca_worst(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::OrcaWorst, chunk_size: 0, tile_align: 128, max_batch }
    }
}
