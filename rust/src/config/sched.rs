//! Scheduler policy configuration: which policy, its chunk/budget sizing,
//! and the paged-KV knobs (block size, admission watermark).

/// Which batching policy the engine runs (§5's comparison set plus the
/// Sarathi-Serve-style hybrid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// FasterTransformer-style request-level scheduling: prefill-only then
    /// decode-only batches, next batch only when the whole batch completes.
    RequestLevel,
    /// Orca iteration-level scheduling, best case (§5.2): one *full* prefill
    /// may overlap running decodes each iteration.
    OrcaBest,
    /// Orca worst case: all requests enter/leave together — degenerates to
    /// prefill-only/decode-only batches.
    OrcaWorst,
    /// SARATHI: chunked-prefills + decode-maximal batching (one prefill
    /// chunk at a time).
    Sarathi,
    /// Sarathi-Serve-style stall-free batching: per-iteration token budget
    /// shared by all running prefill chunks + decodes, over paged KV.
    Hybrid,
}

impl SchedulerKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::RequestLevel => "request-level",
            SchedulerKind::OrcaBest => "orca-best",
            SchedulerKind::OrcaWorst => "orca-worst",
            SchedulerKind::Sarathi => "sarathi",
            SchedulerKind::Hybrid => "hybrid",
        }
    }

    /// Parse a CLI name (the inverse of [`name`](Self::name); "baseline"
    /// is accepted for request-level).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "request-level" | "baseline" => SchedulerKind::RequestLevel,
            "orca-best" => SchedulerKind::OrcaBest,
            "orca-worst" => SchedulerKind::OrcaWorst,
            "sarathi" => SchedulerKind::Sarathi,
            "hybrid" => SchedulerKind::Hybrid,
            _ => return None,
        })
    }
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    pub kind: SchedulerKind,
    /// SARATHI chunk size C (tokens). Ignored by other policies.
    pub chunk_size: usize,
    /// Tile size the fused token count is aligned to (§4.4: the prefill
    /// chunk shrinks so chunk + piggybacked decodes is a tile multiple).
    pub tile_align: usize,
    /// Maximum batch size B (from the §4.3.1 capacity formula for the slot
    /// policies; a sequence cap for the hybrid policy).
    pub max_batch: usize,
    /// Hybrid: per-iteration budget on fused tokens (prefill chunk tokens
    /// + one per decode lane). Ignored by other policies.
    pub token_budget: usize,
    /// Paged-KV block size in tokens; 0 means the degenerate
    /// whole-request-slot layout (the seed semantics).
    pub block_size: usize,
    /// Hybrid admission watermark: free blocks reserved for decode growth.
    pub watermark_blocks: usize,
}

impl SchedulerConfig {
    pub fn sarathi(chunk_size: usize, max_batch: usize) -> Self {
        SchedulerConfig {
            kind: SchedulerKind::Sarathi,
            chunk_size,
            tile_align: 128,
            max_batch,
            token_budget: 0,
            block_size: 0,
            watermark_blocks: 0,
        }
    }

    pub fn baseline(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::RequestLevel, ..Self::sarathi(0, max_batch) }
    }

    pub fn orca_best(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::OrcaBest, ..Self::sarathi(0, max_batch) }
    }

    pub fn orca_worst(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::OrcaWorst, ..Self::sarathi(0, max_batch) }
    }

    /// Stall-free token-budget policy. Pair with a paged KV pool via
    /// [`with_block_size`](Self::with_block_size) to lift admission above
    /// the worst-case slot formula.
    pub fn hybrid(token_budget: usize, max_batch: usize) -> Self {
        SchedulerConfig {
            kind: SchedulerKind::Hybrid,
            chunk_size: 0,
            tile_align: 128,
            max_batch,
            token_budget,
            block_size: 0,
            watermark_blocks: 0,
        }
    }

    pub fn with_block_size(mut self, block_size: usize) -> Self {
        self.block_size = block_size;
        self
    }

    pub fn with_watermark(mut self, watermark_blocks: usize) -> Self {
        self.watermark_blocks = watermark_blocks;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for k in [
            SchedulerKind::RequestLevel,
            SchedulerKind::OrcaBest,
            SchedulerKind::OrcaWorst,
            SchedulerKind::Sarathi,
            SchedulerKind::Hybrid,
        ] {
            assert_eq!(SchedulerKind::parse(k.name()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("baseline"), Some(SchedulerKind::RequestLevel));
        assert_eq!(SchedulerKind::parse("nope"), None);
    }

    #[test]
    fn hybrid_builder_sets_paging_knobs() {
        let c = SchedulerConfig::hybrid(256, 16).with_block_size(32).with_watermark(2);
        assert_eq!(c.kind, SchedulerKind::Hybrid);
        assert_eq!(c.token_budget, 256);
        assert_eq!(c.block_size, 32);
        assert_eq!(c.watermark_blocks, 2);
    }
}
