//! Scheduler policy configuration: which policy, its chunk/budget sizing,
//! and the paged-KV knobs (block size, admission watermark).

/// Which batching policy the engine runs (§5's comparison set plus the
/// Sarathi-Serve-style hybrid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// FasterTransformer-style request-level scheduling: prefill-only then
    /// decode-only batches, next batch only when the whole batch completes.
    RequestLevel,
    /// Orca iteration-level scheduling, best case (§5.2): one *full* prefill
    /// may overlap running decodes each iteration.
    OrcaBest,
    /// Orca worst case: all requests enter/leave together — degenerates to
    /// prefill-only/decode-only batches.
    OrcaWorst,
    /// SARATHI: chunked-prefills + decode-maximal batching (one prefill
    /// chunk at a time).
    Sarathi,
    /// Sarathi-Serve-style stall-free batching: per-iteration token budget
    /// shared by all running prefill chunks + decodes, over paged KV.
    Hybrid,
}

impl SchedulerKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::RequestLevel => "request-level",
            SchedulerKind::OrcaBest => "orca-best",
            SchedulerKind::OrcaWorst => "orca-worst",
            SchedulerKind::Sarathi => "sarathi",
            SchedulerKind::Hybrid => "hybrid",
        }
    }

    /// Parse a CLI name (the inverse of [`name`](Self::name); "baseline"
    /// is accepted for request-level).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "request-level" | "baseline" => SchedulerKind::RequestLevel,
            "orca-best" => SchedulerKind::OrcaBest,
            "orca-worst" => SchedulerKind::OrcaWorst,
            "sarathi" => SchedulerKind::Sarathi,
            "hybrid" => SchedulerKind::Hybrid,
            _ => return None,
        })
    }
}

/// What happens to a preempted request's KV (Sarathi-Serve §B /
/// DistServe, arXiv 2401.09670): swap it over the host link and back, or
/// drop it and pay a recompute charge on resume. Priced by
/// [`crate::coordinator::SwapCost`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PreemptionMode {
    /// KV crosses the host link (PCIe) on swap-out AND swap-in.
    #[default]
    Swap,
    /// KV is dropped for free; resume pays a recompute charge instead.
    Recompute,
}

impl PreemptionMode {
    pub fn name(&self) -> &'static str {
        match self {
            PreemptionMode::Swap => "swap",
            PreemptionMode::Recompute => "recompute",
        }
    }

    /// Parse a CLI name (the inverse of [`name`](Self::name)).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "swap" => PreemptionMode::Swap,
            "recompute" => PreemptionMode::Recompute,
            _ => return None,
        })
    }
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    pub kind: SchedulerKind,
    /// SARATHI chunk size C (tokens). Ignored by other policies.
    pub chunk_size: usize,
    /// Tile size the fused token count is aligned to (§4.4: the prefill
    /// chunk shrinks so chunk + piggybacked decodes is a tile multiple).
    pub tile_align: usize,
    /// Maximum batch size B (from the §4.3.1 capacity formula for the slot
    /// policies; a sequence cap for the hybrid policy).
    pub max_batch: usize,
    /// Hybrid: per-iteration budget on fused tokens (prefill chunk tokens
    /// + one per decode lane). Ignored by other policies.
    pub token_budget: usize,
    /// Paged-KV block size in tokens; 0 means the degenerate
    /// whole-request-slot layout (the seed semantics).
    pub block_size: usize,
    /// Hybrid admission watermark: free blocks reserved for decode growth.
    pub watermark_blocks: usize,
    /// How preempted KV is recovered (and therefore priced).
    pub preemption: PreemptionMode,
    /// Open-loop serving stance: reject infeasible requests into a
    /// terminal state instead of panicking the whole run (see
    /// [`crate::coordinator::InfeasiblePolicy`]). Figure-repro /
    /// closed-loop runs keep the default loud panic.
    pub reject_infeasible: bool,
    /// Copy-on-write prefix sharing over the paged block map (hybrid-only;
    /// `--prefix-share` on the CLI): requests tagged with a
    /// [`PrefixSpec`] whose prefix is already resident reserve and compute
    /// only their non-shared tokens.
    ///
    /// [`PrefixSpec`]: crate::workload::PrefixSpec
    pub prefix_share: bool,
    /// Bounded cache-aware waiting (`--max-prefix-wait`): consecutive
    /// no-progress admission attempts before a prefix waiter degrades to
    /// a full-price miss. `0` = never wait — every would-be wait is an
    /// immediate fallback ([`Admission::max_prefix_wait`]).
    ///
    /// [`Admission::max_prefix_wait`]:
    ///     crate::coordinator::sched::Admission::max_prefix_wait
    pub max_prefix_wait: usize,
    /// Head-of-line bypass window behind an observably stalled prefix
    /// waiter (`--bypass-window`). `0` = window closed — the strict FCFS
    /// gate ([`Admission::bypass_window`]).
    ///
    /// [`Admission::bypass_window`]:
    ///     crate::coordinator::sched::Admission::bypass_window
    pub bypass_window: usize,
}

impl SchedulerConfig {
    pub fn sarathi(chunk_size: usize, max_batch: usize) -> Self {
        use crate::coordinator::sched::Admission;
        SchedulerConfig {
            kind: SchedulerKind::Sarathi,
            chunk_size,
            tile_align: 128,
            max_batch,
            token_budget: 0,
            block_size: 0,
            watermark_blocks: 0,
            preemption: PreemptionMode::Swap,
            reject_infeasible: false,
            prefix_share: false,
            max_prefix_wait: Admission::DEFAULT_MAX_PREFIX_WAIT,
            bypass_window: Admission::DEFAULT_BYPASS_WINDOW,
        }
    }

    pub fn baseline(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::RequestLevel, ..Self::sarathi(0, max_batch) }
    }

    pub fn orca_best(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::OrcaBest, ..Self::sarathi(0, max_batch) }
    }

    pub fn orca_worst(max_batch: usize) -> Self {
        SchedulerConfig { kind: SchedulerKind::OrcaWorst, ..Self::sarathi(0, max_batch) }
    }

    /// Stall-free token-budget policy. Pair with a paged KV pool via
    /// [`with_block_size`](Self::with_block_size) to lift admission above
    /// the worst-case slot formula.
    pub fn hybrid(token_budget: usize, max_batch: usize) -> Self {
        // watermark stays 0 for the degenerate slot layout (no growth, so
        // nothing to reserve); with_block_size raises it — under the
        // costed swap path, admitting to zero free blocks forces a
        // preemption on the very next decode step, and each one now pays
        // KV-bytes-over-PCIe, so a small standing reserve is cheaper than
        // the transfer churn.
        SchedulerConfig {
            kind: SchedulerKind::Hybrid,
            token_budget,
            ..Self::sarathi(0, max_batch)
        }
    }

    /// Default decode-growth reserve for paged pools (revisited against
    /// the costed swap path — see `watermark_blocks` in
    /// [`hybrid`](Self::hybrid)).
    pub const PAGED_WATERMARK: usize = 2;

    /// Switch to a paged KV pool of `block_size`-token blocks; raises the
    /// admission watermark to [`Self::PAGED_WATERMARK`] when unset.
    pub fn with_block_size(mut self, block_size: usize) -> Self {
        self.block_size = block_size;
        if block_size > 0 && self.watermark_blocks == 0 {
            self.watermark_blocks = Self::PAGED_WATERMARK;
        }
        self
    }

    pub fn with_watermark(mut self, watermark_blocks: usize) -> Self {
        self.watermark_blocks = watermark_blocks;
        self
    }

    pub fn with_preemption(mut self, mode: PreemptionMode) -> Self {
        self.preemption = mode;
        self
    }

    /// Open-loop stance: reject infeasible requests instead of panicking.
    pub fn with_reject_infeasible(mut self) -> Self {
        self.reject_infeasible = true;
        self
    }

    /// Copy-on-write prefix sharing over the paged block map
    /// (hybrid-only — `make_scheduler` asserts the pairing).
    pub fn with_prefix_share(mut self) -> Self {
        self.prefix_share = true;
        self
    }

    /// Bounded-wait fallback knob (0 = never wait).
    pub fn with_max_prefix_wait(mut self, k: usize) -> Self {
        self.max_prefix_wait = k;
        self
    }

    /// Head-of-line bypass window (0 = strict FCFS).
    pub fn with_bypass_window(mut self, window: usize) -> Self {
        self.bypass_window = window;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for k in [
            SchedulerKind::RequestLevel,
            SchedulerKind::OrcaBest,
            SchedulerKind::OrcaWorst,
            SchedulerKind::Sarathi,
            SchedulerKind::Hybrid,
        ] {
            assert_eq!(SchedulerKind::parse(k.name()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("baseline"), Some(SchedulerKind::RequestLevel));
        assert_eq!(SchedulerKind::parse("nope"), None);
    }

    #[test]
    fn hybrid_builder_sets_paging_knobs() {
        let c = SchedulerConfig::hybrid(256, 16).with_block_size(32).with_watermark(2);
        assert_eq!(c.kind, SchedulerKind::Hybrid);
        assert_eq!(c.token_budget, 256);
        assert_eq!(c.block_size, 32);
        assert_eq!(c.watermark_blocks, 2);
    }

    #[test]
    fn paged_pools_get_a_default_watermark() {
        // degenerate layout reserves nothing; switching to paged raises the
        // watermark (costed swaps make zero-headroom admission expensive)
        let c = SchedulerConfig::hybrid(256, 16);
        assert_eq!(c.watermark_blocks, 0);
        let c = c.with_block_size(32);
        assert_eq!(c.watermark_blocks, SchedulerConfig::PAGED_WATERMARK);
        // an explicit choice is never overridden
        let c = SchedulerConfig::hybrid(256, 16).with_watermark(5).with_block_size(32);
        assert_eq!(c.watermark_blocks, 5);
    }

    #[test]
    fn preemption_mode_round_trips_and_flags_compose() {
        for m in [PreemptionMode::Swap, PreemptionMode::Recompute] {
            assert_eq!(PreemptionMode::parse(m.name()), Some(m));
        }
        assert_eq!(PreemptionMode::parse("nope"), None);
        let c = SchedulerConfig::hybrid(256, 16)
            .with_preemption(PreemptionMode::Recompute)
            .with_reject_infeasible();
        assert_eq!(c.preemption, PreemptionMode::Recompute);
        assert!(c.reject_infeasible);
        assert!(!SchedulerConfig::sarathi(256, 8).reject_infeasible);
    }

    #[test]
    fn prefix_share_flag_composes() {
        let c = SchedulerConfig::hybrid(256, 16).with_block_size(32).with_prefix_share();
        assert!(c.prefix_share);
        assert!(!SchedulerConfig::hybrid(256, 16).prefix_share);
    }

    /// The fallback-policy knobs default to the admission gate's values
    /// and thread through `make_scheduler` into the hybrid gate — with
    /// `0` keeping its admission semantics (never wait / window closed).
    #[test]
    fn prefix_wait_knobs_thread_into_the_admission_gate() {
        use crate::coordinator::sched::{make_scheduler, Admission};
        let c = SchedulerConfig::hybrid(256, 16);
        assert_eq!(c.max_prefix_wait, Admission::DEFAULT_MAX_PREFIX_WAIT);
        assert_eq!(c.bypass_window, Admission::DEFAULT_BYPASS_WINDOW);
        let c = c
            .with_block_size(32)
            .with_prefix_share()
            .with_max_prefix_wait(0)
            .with_bypass_window(0);
        let sched = make_scheduler(&c);
        let gate = sched.admission();
        assert_eq!(gate.max_prefix_wait, 0, "0 = never wait");
        assert_eq!(gate.bypass_window, 0, "0 = strict FCFS gate");
        assert!(gate.prefix_share);
        // non-zero values thread unchanged
        let gate = make_scheduler(
            &SchedulerConfig::hybrid(256, 16).with_max_prefix_wait(3).with_bypass_window(7),
        )
        .admission();
        assert_eq!(gate.max_prefix_wait, 3);
        assert_eq!(gate.bypass_window, 7);
    }
}
