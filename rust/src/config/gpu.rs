//! GPU hardware models. Peak numbers are public spec sheets; the `eff_*`
//! factors are the calibration constants of the roofline cost model (fit to
//! the paper's published measurements — see costmodel/ and EXPERIMENTS.md).

#[derive(Clone, Debug, PartialEq)]
pub struct GpuConfig {
    pub name: &'static str,
    /// Device memory in bytes.
    pub mem_bytes: f64,
    /// Peak dense fp16 tensor-core TFLOP/s.
    pub peak_tflops: f64,
    /// Peak HBM bandwidth, GB/s.
    pub peak_bw_gbps: f64,
    /// Matmul tile size — matrix dims not divisible by this waste compute
    /// (the paper's Fig. 7 tile-quantization effect).
    pub tile: usize,
    /// Achieved fraction of peak FLOPs for large matmuls (calibrated).
    pub eff_matmul: f64,
    /// Achieved fraction of peak bandwidth for weight streams (calibrated).
    pub eff_weight_bw: f64,
    /// Achieved fraction of peak bandwidth for attention KV streams
    /// (calibrated; attention kernels stream more regularly).
    pub eff_attn_bw: f64,
    /// Achieved fraction of peak FLOPs for attention matmuls (calibrated —
    /// attention GEMMs are skinnier than the big linear ops).
    pub eff_attn_flops: f64,
    /// Token count at which linear-operator matmuls reach full efficiency
    /// for a reference hidden size of 5120 (Fig. 4a saturation point;
    /// scaled by (5120/H)² per model — wider layers saturate earlier,
    /// §4.2). Calibrated: A6000 saturates LLaMA-13B prefill at ~512
    /// tokens; A100 needs ~2.5× more (§5.1.2's FLOPS:BW argument).
    pub sat_tokens_ref: f64,
    /// Matmul utilization floor as token count → 0 (latency-bound regime).
    /// Calibrated so a 256-token chunk loses ~12.5% of peak prefill throughput on A6000 (§4.2) and the Fig.-7 jump shape holds.
    pub sat_ramp_alpha: f64,
    /// Attention-kernel saturation: query count at which the attention
    /// kernel reaches full FLOP efficiency (few-query chunks underutilize
    /// SMs — calibrated to Fig. 13a's ~3× attention overhead at chunk 64).
    pub attn_sat_tokens: f64,
    /// Attention utilization floor as query count → 0.
    pub attn_ramp_alpha: f64,
    /// Fixed per-operator launch overhead, seconds.
    pub kernel_overhead_s: f64,
    /// Point-to-point inter-node link bandwidth for PP activations, GB/s.
    pub p2p_bw_gbps: f64,
    /// Host link (PCIe) bandwidth for KV swap-out/swap-in, GB/s —
    /// the DistServe-style price of preempting a request (arXiv
    /// 2401.09670 charges KV movement at exactly this edge).
    pub host_bw_gbps: f64,
    /// Replica-to-replica interconnect bandwidth for KV handoff in
    /// disaggregated topologies, GB/s — the NVLink/IB-class fabric edge,
    /// distinct from the PCIe `host_bw_gbps` swap path (DistServe §4.3
    /// prices prefill→decode KV migration on this link).
    pub interconnect_gbps: f64,
    /// All-reduce effective bandwidth for TP collectives (NVLink), GB/s.
    pub allreduce_bw_gbps: f64,
}

impl GpuConfig {
    /// NVIDIA RTX A6000: 48 GB, 768 GB/s, ~155 dense fp16 TFLOPs.
    /// FLOPs:BW ≈ 53 in the paper's fp32-ish accounting (§5.1.2).
    pub fn a6000() -> Self {
        GpuConfig {
            name: "a6000",
            mem_bytes: 48.0e9,
            peak_tflops: 154.8,
            peak_bw_gbps: 768.0,
            tile: 128,
            // Calibration (see EXPERIMENTS.md §Calibration):
            //  - saturated prefill ≈ 180 tokens/ms for one LLaMA-13B layer
            //    (Fig. 4a) → ~88.6 effective matmul TFLOPs → 0.57 of peak.
            //  - decode per-token at B=1 is 200× prefill (Fig. 3)
            //    → weight stream at ~444 GB/s → 0.58 of peak.
            //  - decode attention at ~590 GB/s → 0.77 of peak.
            eff_matmul: 0.57,
            eff_weight_bw: 0.58,
            eff_attn_bw: 0.77,
            eff_attn_flops: 0.28,
            sat_tokens_ref: 512.0,
            sat_ramp_alpha: 0.78,
            attn_sat_tokens: 256.0,
            attn_ramp_alpha: 0.22,
            kernel_overhead_s: 5.0e-6,
            p2p_bw_gbps: 25.0,
            // PCIe 4.0 x16 ≈ 32 GB/s peak; ~25 effective for bulk copies
            host_bw_gbps: 25.0,
            // IB HDR-class fabric between replicas; 2× the host link
            interconnect_gbps: 50.0,
            allreduce_bw_gbps: 300.0,
        }
    }

    /// NVIDIA A100-80GB: 80 GB, 2039 GB/s, 312 dense fp16 TFLOPs.
    /// FLOPS:BW ≈ 156 (§5.1.2) → needs larger chunks to saturate.
    pub fn a100() -> Self {
        GpuConfig {
            name: "a100",
            mem_bytes: 80.0e9,
            peak_tflops: 312.0,
            peak_bw_gbps: 2039.0,
            tile: 128,
            eff_matmul: 0.57,
            eff_weight_bw: 0.58,
            eff_attn_bw: 0.77,
            eff_attn_flops: 0.28,
            sat_tokens_ref: 1280.0,
            sat_ramp_alpha: 0.78,
            attn_sat_tokens: 512.0,
            attn_ramp_alpha: 0.22,
            kernel_overhead_s: 5.0e-6,
            p2p_bw_gbps: 25.0,
            host_bw_gbps: 25.0,
            interconnect_gbps: 50.0,
            allreduce_bw_gbps: 300.0,
        }
    }

    /// Effective matmul FLOP/s (not TFLOP/s).
    pub fn matmul_flops(&self) -> f64 {
        self.peak_tflops * 1e12 * self.eff_matmul
    }

    pub fn attn_flops(&self) -> f64 {
        self.peak_tflops * 1e12 * self.eff_attn_flops
    }

    /// Effective weight-stream bandwidth, bytes/s.
    pub fn weight_bw(&self) -> f64 {
        self.peak_bw_gbps * 1e9 * self.eff_weight_bw
    }

    pub fn attn_bw(&self) -> f64 {
        self.peak_bw_gbps * 1e9 * self.eff_attn_bw
    }

    /// The compute:bandwidth ratio that determines the saturation point
    /// (tokens needed for a compute-bound linear op).
    pub fn flops_to_bw_ratio(&self) -> f64 {
        (self.peak_tflops * 1e12) / (self.peak_bw_gbps * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_saturates_later_than_a6000() {
        // §5.1.2: the A100's higher FLOPS:BW means larger chunks are needed
        // to keep prefill efficient (the paper's fp32 accounting says
        // ≈53 vs ≈156; with tensor-core peaks the calibrated saturation
        // points carry the effect instead).
        let a = GpuConfig::a6000().sat_tokens_ref;
        let b = GpuConfig::a100().sat_tokens_ref;
        assert!(b > 2.0 * a, "{b} vs {a}");
    }

    #[test]
    fn effective_rates_below_peak() {
        for g in [GpuConfig::a6000(), GpuConfig::a100()] {
            assert!(g.matmul_flops() < g.peak_tflops * 1e12);
            assert!(g.weight_bw() < g.peak_bw_gbps * 1e9);
            assert!(g.attn_bw() < g.peak_bw_gbps * 1e9);
        }
    }
}
