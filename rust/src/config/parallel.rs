//! Parallelism layout: tensor-parallel within a node, pipeline-parallel
//! across nodes, independent replicas above both (§2.3, §5.3).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Tensor-parallel degree (shards every layer).
    pub tp: usize,
    /// Pipeline-parallel degree (splits layers into stages).
    pub pp: usize,
    /// Independent serving replicas (each replica is a tp×pp group).
    pub replicas: usize,
}

impl ParallelConfig {
    pub fn single() -> Self {
        ParallelConfig { tp: 1, pp: 1, replicas: 1 }
    }

    pub fn tp_pp(tp: usize, pp: usize) -> Self {
        ParallelConfig { tp, pp, replicas: 1 }
    }

    pub fn with_replicas(mut self, r: usize) -> Self {
        self.replicas = r;
        self
    }

    pub fn gpus_per_replica(&self) -> usize {
        self.tp * self.pp
    }

    pub fn total_gpus(&self) -> usize {
        self.gpus_per_replica() * self.replicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_counts() {
        // §5.3 deployment: 8-way TP × 8-way PP = 64 GPUs
        assert_eq!(ParallelConfig::tp_pp(8, 8).total_gpus(), 64);
        // alternative: 8 replicas of 8-way TP
        assert_eq!(ParallelConfig::tp_pp(8, 1).with_replicas(8).total_gpus(), 64);
    }
}
