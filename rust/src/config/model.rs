//! Model architectures (paper §4.5: LLaMA-13B, LLaMA-33B, GPT-3, plus the
//! tiny model served for real through PJRT).

/// Transformer decoder architecture in the paper's Table-1 shape language:
/// preproj [H,3H], attn, postproj [H,H], ffn_ln1 [H,H2], ffn_ln2 [H2,H].
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub hidden: usize,      // H
    pub ffn_hidden: usize,  // H2
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    /// Bytes per weight element (fp16 on GPU deployments, f32 for tiny).
    pub bytes_per_param: usize,
}

impl ModelConfig {
    /// LLaMA-13B per the public architecture card (§4.5).
    pub fn llama13b() -> Self {
        ModelConfig { name: "llama-13b", hidden: 5120, ffn_hidden: 13824, n_layers: 40, n_heads: 40, vocab: 32000, bytes_per_param: 2 }
    }

    /// LLaMA-33B (§4.5: 60 layers, 52 heads, hidden 6656).
    pub fn llama33b() -> Self {
        ModelConfig { name: "llama-33b", hidden: 6656, ffn_hidden: 17920, n_layers: 60, n_heads: 52, vocab: 32000, bytes_per_param: 2 }
    }

    /// GPT-3 175B (§4.5: 96 layers, 96 heads, hidden 12288).
    pub fn gpt3() -> Self {
        ModelConfig { name: "gpt3-175b", hidden: 12288, ffn_hidden: 49152, n_layers: 96, n_heads: 96, vocab: 50257, bytes_per_param: 2 }
    }

    /// The tiny model actually served end-to-end through PJRT (matches
    /// python/compile/configs.py).
    pub fn tiny() -> Self {
        ModelConfig { name: "tiny", hidden: 128, ffn_hidden: 512, n_layers: 2, n_heads: 4, vocab: 256, bytes_per_param: 4 }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    /// Parameter count from the Table-1 operator shapes (qkv 3H², out H²,
    /// ffn 2·H·H2 per layer, plus embedding).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let h2 = self.ffn_hidden as f64;
        let per_layer = 4.0 * h * h + 2.0 * h * h2;
        self.n_layers as f64 * per_layer + self.vocab as f64 * h
    }

    pub fn weight_bytes(&self) -> f64 {
        self.param_count() * self.bytes_per_param as f64
    }

    /// m_kv of §4.3.1: bytes of K+V cached per token across all layers.
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.hidden * self.n_layers * self.bytes_per_param) as f64
    }

    /// Linear-operator FLOPs per token per layer (2·m·k·n with m=1):
    /// preproj 6H² + postproj 2H² + ffn 4·H·H2.
    pub fn linear_flops_per_token_per_layer(&self) -> f64 {
        let h = self.hidden as f64;
        let h2 = self.ffn_hidden as f64;
        8.0 * h * h + 4.0 * h * h2
    }

    /// Linear-operator weight bytes streamed per layer (the quantity a
    /// decode-only iteration is bound by).
    pub fn linear_weight_bytes_per_layer(&self) -> f64 {
        let h = self.hidden as f64;
        let h2 = self.ffn_hidden as f64;
        (4.0 * h * h + 2.0 * h * h2) * self.bytes_per_param as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_in_the_right_ballpark() {
        // Table-1 shape params undercount vs. marketing names (gated FFN,
        // biases...), but must be the right order: ~10B / ~25B / ~175B.
        let p13 = ModelConfig::llama13b().param_count();
        assert!((9.0e9..13.5e9).contains(&p13), "{p13}");
        let p33 = ModelConfig::llama33b().param_count();
        assert!((24.0e9..34.0e9).contains(&p33), "{p33}");
        let p175 = ModelConfig::gpt3().param_count();
        assert!((170.0e9..180.0e9).contains(&p175), "{p175}");
    }

    #[test]
    fn kv_bytes_match_hand_calc_llama13b() {
        // 2 (K,V) × 5120 × 40 layers × 2 bytes = 819200 B/token
        assert_eq!(ModelConfig::llama13b().kv_bytes_per_token(), 819_200.0);
    }

    #[test]
    fn linear_flops_match_hand_calc() {
        // 8·H² + 4·H·H2 for LLaMA-13B = 8·5120² + 4·5120·13824
        let f = ModelConfig::llama13b().linear_flops_per_token_per_layer();
        assert_eq!(f, 8.0 * 5120.0 * 5120.0 + 4.0 * 5120.0 * 13824.0);
    }

    #[test]
    fn head_dim_divides() {
        for m in [ModelConfig::llama13b(), ModelConfig::llama33b(), ModelConfig::gpt3(), ModelConfig::tiny()] {
            assert_eq!(m.head_dim() * m.n_heads, m.hidden, "{}", m.name);
        }
    }
}
