//! Configuration: model architectures, GPU hardware, parallelism layouts and
//! scheduler policies. Presets mirror the paper's Table 3 deployments.

mod gpu;
mod model;
mod parallel;
mod sched;

pub use gpu::GpuConfig;
pub use model::ModelConfig;
pub use parallel::ParallelConfig;
pub use sched::{PreemptionMode, SchedulerConfig, SchedulerKind};

/// A full deployment: model × hardware × parallelism. The unit every
/// experiment is parameterized by.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub model: ModelConfig,
    pub gpu: GpuConfig,
    pub parallel: ParallelConfig,
    /// Maximum total sequence length (P + D) requests may reach; bounds the
    /// KV-slot capacity formula (§4.3.1).
    pub max_seq_len: usize,
    /// Fraction of post-weights GPU memory usable for KV cache (the rest is
    /// activations/workspace). Calibrated so the capacity formula lands on
    /// the paper's reported max batch sizes (18/10/6 for LLaMA-13B at
    /// 1K/2K/3K on A6000 — §5.2).
    pub kv_mem_fraction: f64,
    /// Optional override of the computed max batch size (the paper fixes
    /// B=27 / B=11 for the GPT-3 deployments in §5.3).
    pub batch_cap: Option<usize>,
}

impl Deployment {
    pub fn new(model: ModelConfig, gpu: GpuConfig, max_seq_len: usize) -> Self {
        Deployment {
            model,
            gpu,
            parallel: ParallelConfig::single(),
            max_seq_len,
            kv_mem_fraction: 0.56,
            batch_cap: None,
        }
    }

    pub fn with_parallel(mut self, p: ParallelConfig) -> Self {
        self.parallel = p;
        self
    }

    pub fn with_batch_cap(mut self, cap: usize) -> Self {
        self.batch_cap = Some(cap);
        self
    }

    /// Per-GPU bytes of model weights under the parallelism layout: TP
    /// shards every layer, PP splits layers across stages.
    pub fn weight_bytes_per_gpu(&self) -> f64 {
        self.model.weight_bytes() / (self.parallel.tp * self.parallel.pp) as f64
    }

    /// Per-GPU KV bytes per token of one request (TP shards heads; a PP
    /// stage holds only its own layers' KV).
    pub fn kv_bytes_per_token_per_gpu(&self) -> f64 {
        self.model.kv_bytes_per_token() / (self.parallel.tp * self.parallel.pp) as f64
    }

    /// §4.3.1 capacity formula: B = floor((M_G − M_S) / (L · m_kv)), with
    /// the usable-memory fraction applied. Returns at least 1.
    pub fn max_batch_size(&self) -> usize {
        if let Some(cap) = self.batch_cap {
            return cap;
        }
        let free = self.gpu.mem_bytes - self.weight_bytes_per_gpu();
        if free <= 0.0 {
            return 1;
        }
        let per_req = self.max_seq_len as f64 * self.kv_bytes_per_token_per_gpu();
        ((free * self.kv_mem_fraction / per_req).floor() as usize).max(1)
    }

    /// Total KV capacity in **tokens** under the same memory budget the
    /// §4.3.1 slot formula divides up. The paged allocator spends this
    /// token pool directly instead of reserving `max_seq_len` per request,
    /// which is why it admits strictly more concurrent requests whenever
    /// actual sequences run shorter than the worst case. (With a
    /// `batch_cap` override the pool is the cap's worst-case footprint, so
    /// slot and paged accounting stay comparable.)
    pub fn kv_capacity_tokens(&self) -> usize {
        if let Some(cap) = self.batch_cap {
            return cap * self.max_seq_len;
        }
        let free = self.gpu.mem_bytes - self.weight_bytes_per_gpu();
        if free <= 0.0 {
            return self.max_seq_len;
        }
        ((free * self.kv_mem_fraction / self.kv_bytes_per_token_per_gpu()).floor() as usize)
            .max(self.max_seq_len)
    }

    /// Number of paged KV blocks of `block_size` tokens that fit the
    /// deployment's KV memory budget.
    pub fn kv_blocks(&self, block_size: usize) -> usize {
        (self.kv_capacity_tokens() / block_size.max(1)).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The capacity formula must land on the batch sizes the paper reports
    /// (§5.2: max fit 18 / 10-9 / 6 for LLaMA-13B on A6000 at 1K/2K/3K;
    /// Table 4: 10 / 5 / 3 for LLaMA-33B on A100).
    #[test]
    fn capacity_formula_matches_paper_llama13b_a6000() {
        let b: Vec<usize> = [1024, 2048, 3072]
            .iter()
            .map(|&l| Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), l).max_batch_size())
            .collect();
        assert_eq!(b[0], 18);
        assert!(b[1] == 9 || b[1] == 10, "2K batch {}", b[1]);
        assert_eq!(b[2], 6);
    }

    #[test]
    fn capacity_formula_matches_paper_llama33b_a100() {
        let b: Vec<usize> = [1024, 2048, 3072]
            .iter()
            .map(|&l| Deployment::new(ModelConfig::llama33b(), GpuConfig::a100(), l).max_batch_size())
            .collect();
        assert_eq!(b[0], 10);
        assert_eq!(b[1], 5);
        assert_eq!(b[2], 3);
    }

    #[test]
    fn batch_cap_overrides_formula() {
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 8))
            .with_batch_cap(27);
        assert_eq!(d.max_batch_size(), 27);
    }

    #[test]
    fn tp_sharding_frees_memory() {
        let single = Deployment::new(ModelConfig::llama33b(), GpuConfig::a100(), 1024);
        let tp2 = single.clone().with_parallel(ParallelConfig::tp_pp(2, 1));
        assert!(tp2.max_batch_size() > single.max_batch_size());
    }

    #[test]
    fn oversized_model_yields_min_batch() {
        // GPT-3 never fits one A100 — formula must degrade gracefully.
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 2048);
        assert_eq!(d.max_batch_size(), 1);
    }

    #[test]
    fn token_pool_is_consistent_with_slot_formula() {
        let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 1024);
        let tokens = d.kv_capacity_tokens();
        // the slot formula is exactly the token pool divided into
        // worst-case reservations
        assert_eq!(tokens / d.max_seq_len, d.max_batch_size());
        // block pool covers the same memory
        assert_eq!(d.kv_blocks(16), tokens / 16);
        assert!(d.kv_blocks(16) * 16 <= tokens);
    }
}
