#!/usr/bin/env bash
# Local CI gauntlet — mirrors .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")"

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== build (tier 1) =="
cargo build --release

echo "== test (tier 1) =="
cargo test -q

echo "CI gauntlet passed."
