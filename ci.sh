#!/usr/bin/env bash
# Local CI gauntlet — mirrors .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")"

echo "== rustfmt =="
cargo fmt --all -- --check

echo "== clippy =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== build (tier 1) =="
cargo build --release

echo "== test (tier 1) =="
cargo test -q

echo "== smoke: quickstart example (cost-model path without pjrt) =="
cargo run --release --example quickstart

echo "== smoke: pipeline-mode simulate writes a non-empty JSONL trace =="
TRACE="$(mktemp -t pipe_trace.XXXXXX.jsonl)"
cargo run --release -- simulate --requests 80 --pp 4 --scheduler hybrid \
    --block-size 64 --json-out "$TRACE"
test -s "$TRACE" || { echo "empty JSONL trace"; exit 1; }
head -c 200 "$TRACE"; echo
rm -f "$TRACE"

echo "== smoke: prefix-share simulate reports cache hits in report + JSONL =="
PTRACE="$(mktemp -t prefix_trace.XXXXXX.jsonl)"
POUT="$(cargo run --release -- simulate --requests 200 --scheduler hybrid \
    --block-size 32 --prefix-share --num-templates 4 --json-out "$PTRACE")"
echo "$POUT" | grep -E 'prefix_hits=[1-9][0-9]*' \
    || { echo "no prefix hits reported"; exit 1; }
grep -q '"prefix_hits":' "$PTRACE" || { echo "JSONL lacks prefix_hits"; exit 1; }
grep -qE '"shared_kv_tokens":[1-9][0-9]*' "$PTRACE" \
    || { echo "JSONL never shows shared KV occupancy"; exit 1; }
rm -f "$PTRACE"

echo "== smoke: conversation-tree workload — radix partial hits in report + JSONL =="
CTRACE="$(mktemp -t conv_trace.XXXXXX.jsonl)"
COUT="$(cargo run --release -- simulate --requests 160 --scheduler hybrid \
    --block-size 32 --prefix-share --workload conversation \
    --num-templates 4 --prefix-len 256 --json-out "$CTRACE")"
echo "$COUT" | grep -E 'partial_hit_tokens=[1-9][0-9]*' \
    || { echo "conversation run served no partial-hit tokens"; exit 1; }
echo "$COUT" | grep -E 'mean_hit_depth_tokens=[0-9.]+' \
    || { echo "report lacks mean hit depth"; exit 1; }
grep -qE '"prefix_partial_hit_tokens":[1-9][0-9]*' "$CTRACE" \
    || { echo "JSONL never shows partial-hit tokens"; exit 1; }
rm -f "$CTRACE"

echo "== smoke: wedge regression — undersized shared pool + template fanout must exit 0 =="
WTRACE="$(mktemp -t wedge_trace.XXXXXX.jsonl)"
WOUT="$(cargo run --release -- simulate --requests 200 --scheduler hybrid \
    --block-size 32 --kv-blocks 40 --pp 2 --rate 6 \
    --prefix-share --num-templates 4 --prefix-len 384 --json-out "$WTRACE")"
echo "$WOUT" | grep -E 'prefix_fallbacks=[0-9]+' \
    || { echo "report lacks prefix_fallbacks"; exit 1; }
grep -q '"prefix_fallbacks":' "$WTRACE" || { echo "JSONL lacks prefix_fallbacks"; exit 1; }
rm -f "$WTRACE"

echo "== smoke: multi-replica affinity router — hits in report, replica tags in JSONL =="
RTRACE="$(mktemp -t router_trace.XXXXXX.jsonl)"
ROUT="$(cargo run --release -- simulate --requests 240 --scheduler hybrid \
    --block-size 32 --kv-blocks 32 --rate 24 \
    --replicas 4 --router affinity --threads 2 \
    --prefix-share --num-templates 8 --prefix-len 384 --json-out "$RTRACE")"
echo "$ROUT" | grep -E 'prefix_hits=[1-9][0-9]*' \
    || { echo "no aggregate prefix hits reported"; exit 1; }
echo "$ROUT" | grep -E 'load_imbalance=[0-9.]+' \
    || { echo "report lacks load_imbalance"; exit 1; }
grep -q '"replica":' "$RTRACE" || { echo "JSONL lacks replica tags"; exit 1; }
rm -f "$RTRACE"

echo "== smoke: digest routing over conversation trees — hits + imbalance on 4 replicas =="
GOUT="$(cargo run --release -- simulate --requests 160 --scheduler hybrid \
    --block-size 32 --kv-blocks 512 --rate 24 \
    --replicas 4 --router affinity \
    --prefix-share --workload conversation --num-templates 4 --prefix-len 256)"
echo "$GOUT" | grep -E 'prefix_hits=[1-9][0-9]*' \
    || { echo "digest routing found no prefix hits"; exit 1; }
echo "$GOUT" | grep -E 'load_imbalance=[0-9.]+' \
    || { echo "report lacks load_imbalance"; exit 1; }

echo "== smoke: disaggregated topology — goodput in report, kv_transfer_time in JSONL =="
DTRACE="$(mktemp -t disagg_trace.XXXXXX.jsonl)"
DOUT="$(cargo run --release -- simulate --requests 120 --rate 2 \
    --replicas 4 --topology disagg --prefill-replicas 1 \
    --interconnect-gbps 200 --threads 2 --json-out "$DTRACE")"
echo "$DOUT" | grep -E 'topology=disagg' || { echo "report lacks topology"; exit 1; }
echo "$DOUT" | grep -E 'goodput .*attained_frac=[0-9.]+' \
    || { echo "report lacks goodput"; exit 1; }
echo "$DOUT" | grep -E 'kv_transfers=[1-9][0-9]*' \
    || { echo "the fabric moved no KV"; exit 1; }
grep -q '"kv_transfer_time":' "$DTRACE" || { echo "JSONL lacks kv_transfer_time"; exit 1; }
rm -f "$DTRACE"

echo "== smoke: disagg --trace-out — Perfetto timeline with bubble + transfer spans =="
TL="$(mktemp -t disagg_timeline.XXXXXX.json)"
TOUT="$(cargo run --release -- simulate --requests 120 --rate 2 \
    --replicas 4 --topology disagg --prefill-replicas 1 \
    --interconnect-gbps 200 --threads 2 --trace-out "$TL")"
echo "$TOUT" | grep -E 'ttft decomposition \(mean over [1-9][0-9]* requests\).*kv_transfer=[0-9.]+s' \
    || { echo "report lacks the latency decomposition"; exit 1; }
grep -q '"traceEvents":\[' "$TL" || { echo "timeline lacks traceEvents"; exit 1; }
grep -q '"cat":"bubble"' "$TL" || { echo "timeline has no bubble spans"; exit 1; }
grep -q '"cat":"kv-transfer"' "$TL" || { echo "timeline has no transfer lanes"; exit 1; }
grep -q '"cat":"batch"' "$TL" || { echo "timeline has no batch spans"; exit 1; }
rm -f "$TL"

echo "== smoke: soak mode — progress lines, controller activity, streaming JSONL =="
STRACE="$(mktemp -t soak_trace.XXXXXX.jsonl)"
SOUT="$(cargo run --release -- simulate --horizon-secs 40 --flush-every 5 --rate 2 \
    --scheduler hybrid --block-size 32 --target-p99-tbt 0.05 \
    --diurnal-amp 0.4 --diurnal-period 20 --json-out "$STRACE")"
echo "$SOUT" | grep -F '[soak]' >/dev/null || { echo "no soak progress lines"; exit 1; }
echo "$SOUT" | grep -E 'controller_ticks=[1-9][0-9]* controller_adjustments=[0-9]+' \
    || { echo "report lacks controller activity counters"; exit 1; }
echo "$SOUT" | grep -F 'retained first->last checkpoint' >/dev/null \
    || { echo "report lacks retained-memory checkpoints"; exit 1; }
test -s "$STRACE" || { echo "empty soak JSONL trace"; exit 1; }
rm -f "$STRACE"

echo "== bench: hot-path + cluster sweep (quick), BENCH_*.json artifacts + 2x regression gate =="
cargo bench --bench scheduler_hotpath
cargo bench --bench cluster_sweep -- --quick
test -s rust/target/bench/BENCH_hotpath.json || { echo "missing BENCH_hotpath.json"; exit 1; }
test -s rust/target/bench/BENCH_cluster.json || { echo "missing BENCH_cluster.json"; exit 1; }

echo "CI gauntlet passed."
