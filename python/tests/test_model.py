"""L2 correctness: the step functions the rust coordinator schedules.

The central claims verified here (both on the Pallas path and the ref path):
  1. chunked prefill is mathematically equivalent to full prefill (§4.2);
  2. a decode-maximal hybrid step produces exactly the same logits as running
     the prefill chunk and the decode batch separately (§4.3) — fusion
     changes cost, never values;
  3. KV-cache state evolves identically under either schedule.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.configs import TinyConfig, init_params, kv_shape
from compile import model as M

CFG = TinyConfig()
PARAMS = init_params(CFG)
RNG = np.random.default_rng(7)


def fresh_kv():
    k = jnp.zeros(kv_shape(CFG), jnp.float32)
    return k, jnp.zeros_like(k)


def prompt(n):
    return RNG.integers(0, CFG.vocab, size=n).astype(np.int32)


def run_chunked_prefill(tokens, slot, chunk, k, v, use_pallas=True):
    """Prefill `tokens` into `slot` in chunks of size `chunk` (padded last)."""
    logits = None
    n = len(tokens)
    for start in range(0, n, chunk):
        piece = tokens[start:start + chunk]
        clen = len(piece)
        if clen < chunk:  # pad; mask ignores the padding
            piece = np.concatenate([piece, np.zeros(chunk - clen, np.int32)])
        logits, k, v = M.prefill_chunk_step(
            CFG, PARAMS, k, v, jnp.asarray(piece),
            jnp.int32(slot), jnp.int32(start), jnp.int32(clen))
    return logits, k, v


class TestChunkedPrefillEquivalence:
    @pytest.mark.parametrize("n,chunk", [(48, 16), (48, 32), (64, 16), (40, 16)])
    def test_chunked_equals_full(self, n, chunk):
        toks = prompt(n)
        full, kf, vf = M.full_prefill_reference(CFG, PARAMS, toks)
        k, v = fresh_kv()
        chunked, k, v = run_chunked_prefill(toks, 0, chunk, k, v)
        np.testing.assert_allclose(chunked, full, atol=5e-5)
        np.testing.assert_allclose(k[:, 0, :n], kf[:, 0, :n], atol=5e-5)
        np.testing.assert_allclose(v[:, 0, :n], vf[:, 0, :n], atol=5e-5)

    def test_partial_final_chunk_padding_is_harmless(self):
        # 40 = 32 + 8: the final chunk is padded from 8 to 16 tokens; the
        # logits must still match a full prefill of 40 tokens.
        toks = prompt(40)
        full, _, _ = M.full_prefill_reference(CFG, PARAMS, toks)
        k, v = fresh_kv()
        chunked, _, _ = run_chunked_prefill(toks, 0, 32, k, v)
        np.testing.assert_allclose(chunked, full, atol=5e-5)

    def test_pallas_and_ref_paths_agree(self):
        toks = prompt(32)
        k, v = fresh_kv()
        lp, kp, vp = M.prefill_chunk_step(
            CFG, PARAMS, k, v, jnp.asarray(toks), jnp.int32(2), jnp.int32(0),
            jnp.int32(32), use_pallas=True)
        lr, kr, vr = M.prefill_chunk_step(
            CFG, PARAMS, k, v, jnp.asarray(toks), jnp.int32(2), jnp.int32(0),
            jnp.int32(32), use_pallas=False)
        np.testing.assert_allclose(lp, lr, atol=5e-5)
        np.testing.assert_allclose(kp, kr, atol=5e-5)

    def test_two_requests_in_different_slots_do_not_interfere(self):
        ta, tb = prompt(32), prompt(32)
        k, v = fresh_kv()
        la_alone, _, _ = run_chunked_prefill(ta, 0, 16, *fresh_kv())
        _, k, v = run_chunked_prefill(tb, 1, 16, k, v)
        la, k, v = run_chunked_prefill(ta, 0, 16, k, v)
        np.testing.assert_allclose(la, la_alone, atol=5e-5)


class TestDecode:
    def _prefilled(self, n=32, slot=0):
        toks = prompt(n)
        k, v = fresh_kv()
        logits, k, v = run_chunked_prefill(toks, slot, 16, k, v)
        return toks, logits, k, v

    def test_decode_matches_prefill_extension(self):
        # decoding token x at position n must equal prefilling prompt+x
        toks, logits, k, v = self._prefilled(32)
        nxt = int(np.argmax(logits))
        slots = jnp.asarray([0, CFG.scratch_slot, CFG.scratch_slot, CFG.scratch_slot], jnp.int32)
        pos = jnp.asarray([32, 0, 0, 0], jnp.int32)
        dl, k, v = M.decode_step(CFG, PARAMS, k, v,
                                 jnp.asarray([nxt, 0, 0, 0], jnp.int32), slots, pos)
        ext = np.concatenate([toks, [nxt]]).astype(np.int32)
        full, _, _ = M.full_prefill_reference(CFG, PARAMS, ext)
        np.testing.assert_allclose(dl[0], full, atol=5e-5)

    def test_greedy_generation_is_deterministic(self):
        _, logits, k, v = self._prefilled(32)
        seqs = []
        for _ in range(2):
            kk, vv, ll = jnp.array(k), jnp.array(v), logits
            out = []
            for i in range(8):
                nxt = int(np.argmax(np.asarray(ll)[0] if np.asarray(ll).ndim == 2 else ll))
                out.append(nxt)
                ll, kk, vv = M.decode_step(
                    CFG, PARAMS, kk, vv,
                    jnp.asarray([nxt] * 4, jnp.int32),
                    jnp.asarray([0] + [CFG.scratch_slot] * 3, jnp.int32),
                    jnp.asarray([32 + i, 0, 0, 0], jnp.int32))
            seqs.append(out)
        assert seqs[0] == seqs[1]


class TestHybridStep:
    def test_hybrid_equals_separate_prefill_and_decode(self):
        # state: request A fully prefilled (slot 0), request B's prompt to be
        # chunk-prefilled into slot 1 while A decodes — the SARATHI batch.
        ta = prompt(32)
        _, la, k, v = (None, *run_chunked_prefill(ta, 0, 16, *fresh_kv()))
        nxt = int(np.argmax(la))
        tb = prompt(16)

        d_tokens = jnp.asarray([nxt, 0, 0, 0], jnp.int32)
        d_slots = jnp.asarray([0] + [CFG.scratch_slot] * 3, jnp.int32)
        d_pos = jnp.asarray([32, 0, 0, 0], jnp.int32)

        # separate execution
        ks, vs = jnp.array(k), jnp.array(v)
        pl_sep, ks, vs = M.prefill_chunk_step(
            CFG, PARAMS, ks, vs, jnp.asarray(tb), jnp.int32(1), jnp.int32(0), jnp.int32(16))
        dl_sep, ks, vs = M.decode_step(CFG, PARAMS, ks, vs, d_tokens, d_slots, d_pos)

        # fused decode-maximal execution
        pl_h, dl_h, kh, vh = M.hybrid_step(
            CFG, PARAMS, k, v, jnp.asarray(tb), jnp.int32(1), jnp.int32(0),
            jnp.int32(16), d_tokens, d_slots, d_pos)

        np.testing.assert_allclose(pl_h, pl_sep, atol=5e-5)
        np.testing.assert_allclose(dl_h[0], dl_sep[0], atol=5e-5)
        np.testing.assert_allclose(kh, ks, atol=5e-5)
        np.testing.assert_allclose(vh, vs, atol=5e-5)

    def test_hybrid_chain_completes_both_requests(self):
        # B prefills in two hybrid chunks while A decodes twice; final states
        # must match the all-separate schedule.
        ta, tb = prompt(32), prompt(32)
        _, la, k, v = (None, *run_chunked_prefill(ta, 0, 16, *fresh_kv()))
        a_tok = int(np.argmax(la))

        ks, vs = jnp.array(k), jnp.array(v)
        # separate: prefill B fully, then decode A twice
        lb_sep, ks, vs = run_chunked_prefill(tb, 1, 16, ks, vs)
        d_slots = jnp.asarray([0] + [CFG.scratch_slot] * 3, jnp.int32)
        da1, ks, vs = M.decode_step(CFG, PARAMS, ks, vs,
                                    jnp.asarray([a_tok] * 4, jnp.int32), d_slots,
                                    jnp.asarray([32, 0, 0, 0], jnp.int32))
        a2 = int(np.argmax(np.asarray(da1)[0]))
        da2, ks, vs = M.decode_step(CFG, PARAMS, ks, vs,
                                    jnp.asarray([a2] * 4, jnp.int32), d_slots,
                                    jnp.asarray([33, 0, 0, 0], jnp.int32))

        # hybrid: two decode-maximal batches
        lb1, dh1, k, v = M.hybrid_step(
            CFG, PARAMS, k, v, jnp.asarray(tb[:16]), jnp.int32(1), jnp.int32(0),
            jnp.int32(16), jnp.asarray([a_tok] * 4, jnp.int32), d_slots,
            jnp.asarray([32, 0, 0, 0], jnp.int32))
        ah2 = int(np.argmax(np.asarray(dh1)[0]))
        assert ah2 == a2
        lb2, dh2, k, v = M.hybrid_step(
            CFG, PARAMS, k, v, jnp.asarray(tb[16:]), jnp.int32(1), jnp.int32(16),
            jnp.int32(16), jnp.asarray([ah2] * 4, jnp.int32), d_slots,
            jnp.asarray([33, 0, 0, 0], jnp.int32))

        np.testing.assert_allclose(lb2, lb_sep, atol=5e-5)
        np.testing.assert_allclose(dh2[0], np.asarray(da2)[0], atol=5e-5)
        np.testing.assert_allclose(k, ks, atol=5e-5)

    def test_scratch_lane_does_not_corrupt_live_slots(self):
        ta = prompt(32)
        _, la, k, v = (None, *run_chunked_prefill(ta, 0, 16, *fresh_kv()))
        k0 = np.asarray(k[:, 0]).copy()
        # all-scratch decode lanes
        _, k, v = M.decode_step(
            CFG, PARAMS, k, v, jnp.asarray([1, 2, 3, 4], jnp.int32),
            jnp.asarray([CFG.scratch_slot] * 4, jnp.int32),
            jnp.asarray([0, 0, 0, 0], jnp.int32))
        np.testing.assert_array_equal(np.asarray(k[:, 0]), k0)


class TestRope:
    def test_rope_position_zero_is_identity(self):
        x = RNG.normal(size=(3, 2, 16)).astype(np.float32)
        out = M.rope(jnp.asarray(x), jnp.zeros(3, jnp.int32))
        np.testing.assert_allclose(out, x, atol=1e-6)

    def test_rope_is_rotation(self):
        # norms are preserved per (head, pair)
        x = RNG.normal(size=(5, 4, 32)).astype(np.float32)
        out = M.rope(jnp.asarray(x), jnp.arange(5, dtype=jnp.int32) * 7)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(x, axis=-1), rtol=1e-5)
