"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the core
correctness signal gating the AOT step.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.chunked_attn import chunked_attention
from compile.kernels.fused_linear import fused_linear
from compile.kernels.ref import chunked_attention_ref, fused_linear_ref

RNG = np.random.default_rng(0)


def _mk_attn(nh, c, t, d, thr_fn):
    q = RNG.normal(size=(nh, c, d)).astype(np.float32)
    k = RNG.normal(size=(nh, t, d)).astype(np.float32)
    v = RNG.normal(size=(nh, t, d)).astype(np.float32)
    thr = thr_fn(c, t).astype(np.int32)
    return q, k, v, thr


class TestChunkedAttention:
    def test_basic(self):
        q, k, v, thr = _mk_attn(4, 16, 256, 32, lambda c, t: np.arange(c) + 10)
        out = chunked_attention(q, k, v, thr)
        assert_allclose(out, chunked_attention_ref(q, k, v, thr), atol=2e-5)

    def test_first_chunk_pure_causal(self):
        # chunk at start == plain causal attention within the chunk
        q, k, v, thr = _mk_attn(2, 8, 64, 16, lambda c, t: np.arange(c))
        out = chunked_attention(q, k, v, thr, block_k=32)
        assert_allclose(out, chunked_attention_ref(q, k, v, thr), atol=2e-5)

    def test_decode_shape_c1(self):
        # C=1 is the decode lane configuration
        q, k, v, thr = _mk_attn(4, 1, 128, 32, lambda c, t: np.array([100]))
        out = chunked_attention(q, k, v, thr)
        assert out.shape == (4, 1, 32)
        assert_allclose(out, chunked_attention_ref(q, k, v, thr), atol=2e-5)

    def test_threshold_zero_attends_only_first_key(self):
        q, k, v, thr = _mk_attn(1, 1, 64, 8, lambda c, t: np.zeros(c))
        out = chunked_attention(q, k, v, thr)
        # with only key 0 visible, output == v[:, 0]
        assert_allclose(out[:, 0], v[:, 0], atol=2e-5)

    def test_stale_cache_is_masked(self):
        # garbage beyond the threshold must not leak into the output
        q, k, v, thr = _mk_attn(2, 4, 128, 16, lambda c, t: np.arange(c) + 3)
        k2, v2 = k.copy(), v.copy()
        k2[:, 8:] = 1e6  # poison everything past the largest threshold
        v2[:, 8:] = -1e6
        out = chunked_attention(q, k2, v2, thr)
        assert_allclose(out, chunked_attention_ref(q, k, v, thr), atol=2e-5)

    def test_block_k_invariance(self):
        q, k, v, thr = _mk_attn(2, 8, 256, 32, lambda c, t: np.arange(c) + 57)
        o64 = chunked_attention(q, k, v, thr, block_k=64)
        o128 = chunked_attention(q, k, v, thr, block_k=128)
        o256 = chunked_attention(q, k, v, thr, block_k=256)
        assert_allclose(o64, o128, atol=2e-5)
        assert_allclose(o64, o256, atol=2e-5)

    def test_bad_block_k_raises(self):
        q, k, v, thr = _mk_attn(1, 4, 100, 8, lambda c, t: np.arange(c))
        with pytest.raises(ValueError):
            chunked_attention(q, k, v, thr, block_k=64)

    @settings(max_examples=25, deadline=None)
    @given(
        nh=st.sampled_from([1, 2, 4]),
        c=st.sampled_from([1, 4, 8, 16, 32]),
        t_blocks=st.integers(1, 4),
        d=st.sampled_from([8, 16, 32]),
        start=st.integers(0, 60),
    )
    def test_hypothesis_sweep(self, nh, c, t_blocks, d, start):
        t = 64 * t_blocks
        start = min(start, t - c)
        q, k, v, thr = _mk_attn(nh, c, t, d, lambda cc, tt: np.arange(cc) + start)
        out = chunked_attention(q, k, v, thr)
        assert_allclose(out, chunked_attention_ref(q, k, v, thr), atol=3e-5)


class TestFusedLinear:
    def test_basic(self):
        x = RNG.normal(size=(20, 128)).astype(np.float32)
        w = RNG.normal(size=(128, 384)).astype(np.float32)
        assert_allclose(fused_linear(x, w, block_t=4), fused_linear_ref(x, w),
                        atol=1e-4)

    def test_single_tile(self):
        x = RNG.normal(size=(4, 64)).astype(np.float32)
        w = RNG.normal(size=(64, 64)).astype(np.float32)
        assert_allclose(fused_linear(x, w), fused_linear_ref(x, w), atol=1e-4)

    def test_tile_mismatch_raises(self):
        x = RNG.normal(size=(10, 16)).astype(np.float32)
        w = RNG.normal(size=(16, 24)).astype(np.float32)
        with pytest.raises(ValueError):
            fused_linear(x, w, block_t=4, block_o=16)

    @settings(max_examples=25, deadline=None)
    @given(
        t_tiles=st.integers(1, 6),
        bt=st.sampled_from([2, 4, 8, 16]),
        h_in=st.sampled_from([32, 64, 128]),
        o_tiles=st.integers(1, 4),
        bo=st.sampled_from([32, 64, 128]),
    )
    def test_hypothesis_sweep(self, t_tiles, bt, h_in, o_tiles, bo):
        t, h_out = t_tiles * bt, o_tiles * bo
        x = RNG.normal(size=(t, h_in)).astype(np.float32)
        w = RNG.normal(size=(h_in, h_out)).astype(np.float32)
        out = fused_linear(x, w, block_t=bt, block_o=bo)
        assert_allclose(out, fused_linear_ref(x, w), atol=2e-4)
