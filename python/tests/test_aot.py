"""AOT artifact sanity: manifest structure, HLO entry layouts, weight file.

These run against the artifacts/ directory if `make artifacts` has produced
it; otherwise they lower a single variant in-process and check the text.
"""

import os
import re

import numpy as np
import pytest

from compile.configs import TinyConfig, init_params, param_names, param_shapes
from compile import aot

CFG = TinyConfig()
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_param_names_match_shapes():
    names = param_names(CFG)
    shapes = param_shapes(CFG)
    assert set(names) == set(shapes)
    assert names[0] == "embed" and names[-1] == "lnf"
    assert len(names) == 2 + 6 * CFG.n_layers


def test_init_params_deterministic():
    a = init_params(CFG, seed=3)
    b = init_params(CFG, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_lowered_prefill_has_expected_entry_layout():
    text = aot.lower_prefill(CFG, CFG.chunk_sizes[0])
    assert text.startswith("HloModule")
    # entry layout must carry the chunked token input and the KV cache
    assert f"s32[{CFG.chunk_sizes[0]}]" in text
    assert f"f32[{CFG.n_layers},{CFG.kv_slots},{CFG.max_len},{CFG.n_heads},{CFG.head_dim}]" in text
    # logits output
    assert f"f32[{CFG.vocab}]" in text


def test_lowered_hybrid_fuses_token_matrix():
    c, d = CFG.chunk_sizes[0], CFG.decode_slots
    text = aot.lower_hybrid(CFG, c, d)
    # the fused [C+D, H] linear is the decode-maximal signature
    assert f"f32[{c + d},{CFG.hidden}]" in text
    assert f"f32[{d},{CFG.vocab}]" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    def manifest(self):
        with open(os.path.join(ART, "manifest.txt")) as f:
            return f.read().splitlines()

    def test_manifest_header(self):
        lines = self.manifest()
        assert lines[0] == "format 1"
        assert lines[1].startswith("model tiny ")
        assert lines[2].startswith("weights weights.npz ")

    def test_manifest_lists_every_bucket(self):
        body = "\n".join(self.manifest())
        for c in CFG.chunk_sizes:
            assert f"name=prefill_c{c}" in body
            assert f"name=hybrid_c{c}_d{CFG.decode_slots}" in body
        assert f"name=decode_d{CFG.decode_slots}" in body

    def test_artifact_files_exist_and_parse_header(self):
        for line in self.manifest():
            m = re.search(r"file=(\S+)", line)
            if not m:
                continue
            path = os.path.join(ART, m.group(1))
            assert os.path.exists(path), path
            with open(path) as f:
                assert f.readline().startswith("HloModule")

    def test_weights_npz_round_trip(self):
        data = np.load(os.path.join(ART, "weights.npz"))
        names = param_names(CFG)
        assert set(data.files) == set(names)
        for n in names:
            assert data[n].shape == param_shapes(CFG)[n]
            assert data[n].dtype == np.float32
