"""L2 — the JAX model: a LLaMA-style decoder with a slotted KV cache and the
three step functions the rust coordinator schedules:

* ``prefill_chunk_step`` — one chunked-prefill iteration (§4.2): processes a
  fixed-size chunk of the prompt, with the attention mask offset so the chunk
  attends to all previously-prefilled tokens of the same request.
* ``decode_step``       — one batched decode-only iteration (the baseline).
* ``hybrid_step``       — one decode-maximal iteration (§4.3): a single
  prefill chunk plus piggybacked decode lanes; every *linear* operator runs
  fused over the concatenated token matrix (one Pallas GEMM), while the
  attention computations stay separate — exactly the paper's batching rule.

All functions are pure (KV cache in, KV cache out) so they can be lowered
once by ``aot.py`` to fixed-shape HLO text and executed from rust via PJRT.
Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .configs import TinyConfig
from .kernels.chunked_attn import chunked_attention
from .kernels.fused_linear import fused_linear
from .kernels.ref import chunked_attention_ref, fused_linear_ref

EPS = 1e-5


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * w


def rope(x, positions):
    """Rotary position embedding. x: [T, n_heads, head_dim], positions: [T]."""
    t, nh, hd = x.shape
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]   # [T, half]
    cos, sin = jnp.cos(angles)[:, None, :], jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _linear(x, w, use_pallas, block_t):
    if use_pallas:
        return fused_linear(x, w, block_t=block_t, block_o=128)
    return fused_linear_ref(x, w)


def _attn(q, k, v, thresholds, use_pallas):
    if use_pallas:
        return chunked_attention(q, k, v, thresholds, block_k=64)
    return chunked_attention_ref(q, k, v, thresholds)


def _unpack(cfg: TinyConfig, params):
    it = iter(params)
    p = {"embed": next(it), "layers": []}
    for _ in range(cfg.n_layers):
        p["layers"].append(
            dict(
                ln1=next(it), wqkv=next(it), wo=next(it),
                ln2=next(it), w1=next(it), w2=next(it),
            )
        )
    p["lnf"] = next(it)
    return p


def _block_t_for(t: int) -> int:
    """Largest tile <=16 dividing the fused token count (the scheduler keeps
    the token count tile-aligned, so this is 16 on the aligned path)."""
    for bt in (16, 8, 4, 2, 1):
        if t % bt == 0:
            return bt
    return 1


# ---------------------------------------------------------------------------
# the transformer body over an arbitrary set of token rows
# ---------------------------------------------------------------------------

def _run_body(cfg, p, x, positions, kv_update, attention, use_pallas):
    """Shared decoder body.

    x: [T, H] token activations (fused prefill+decode rows for hybrid);
    positions: [T] absolute positions (drives RoPE).
    kv_update(layer, k_rows, v_rows, k_cache, v_cache) -> (k_cache, v_cache)
      writes this step's K/V rows into the cache.
    attention(layer, q, k_cache, v_cache) -> [T, n_heads, head_dim]
      computes attention per the step's masking rule.

    Returns a closure run(k_cache, v_cache) -> (x, k_cache, v_cache).
    """
    t = x.shape[0]
    bt = _block_t_for(t)
    nh, hd = cfg.n_heads, cfg.head_dim

    def run(k_cache, v_cache, x=x):
        for l, lp in enumerate(p["layers"]):
            h = rms_norm(x, lp["ln1"])
            qkv = _linear(h, lp["wqkv"], use_pallas, bt)            # fused preproj
            q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
            q = rope(q.reshape(t, nh, hd), positions)
            k_new = rope(k_new.reshape(t, nh, hd), positions)
            v_new = v_new.reshape(t, nh, hd)
            k_cache, v_cache = kv_update(l, k_new, v_new, k_cache, v_cache)
            att = attention(l, q, k_cache, v_cache)                 # [T, nh, hd]
            att = att.reshape(t, cfg.hidden)
            x = x + _linear(att, lp["wo"], use_pallas, bt)          # fused postproj
            h2 = rms_norm(x, lp["ln2"])
            h2 = _linear(h2, lp["w1"], use_pallas, bt)              # fused ffn_ln1
            h2 = jax.nn.gelu(h2)
            x = x + _linear(h2, lp["w2"], use_pallas, bt)           # fused ffn_ln2
        return rms_norm(x, p["lnf"]), k_cache, v_cache

    return run


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def prefill_chunk_step(cfg: TinyConfig, params, k_cache, v_cache,
                       tokens, slot, start, chunk_len, *, use_pallas=True):
    """One chunked-prefill iteration for a single request.

    tokens: [C] int32 (padded past chunk_len); slot/start/chunk_len: scalars.
    Returns (next_token_logits [vocab], k_cache, v_cache).
    """
    p = _unpack(cfg, params)
    c = tokens.shape[0]
    positions = start + jnp.arange(c, dtype=jnp.int32)
    x = p["embed"][tokens]                                          # [C, H]

    def kv_update(l, k_new, v_new, kc, vc):
        kc = jax.lax.dynamic_update_slice(kc, k_new[None, None], (l, slot, start, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_new[None, None], (l, slot, start, 0, 0))
        return kc, vc

    def attention(l, q, kc, vc):
        krow = jax.lax.dynamic_index_in_dim(kc[l], slot, axis=0, keepdims=False)
        vrow = jax.lax.dynamic_index_in_dim(vc[l], slot, axis=0, keepdims=False)
        # [max_len, nh, hd] -> [nh, max_len, hd]
        krow = krow.transpose(1, 0, 2)
        vrow = vrow.transpose(1, 0, 2)
        out = _attn(q.transpose(1, 0, 2), krow, vrow, positions, use_pallas)
        return out.transpose(1, 0, 2)                               # [C, nh, hd]

    run = _run_body(cfg, p, x, positions, kv_update, attention, use_pallas)
    x, k_cache, v_cache = run(k_cache, v_cache)
    last = jax.lax.dynamic_index_in_dim(x, chunk_len - 1, axis=0, keepdims=False)
    logits = last @ p["embed"].T                                    # tied unembed
    return logits, k_cache, v_cache


def decode_step(cfg: TinyConfig, params, k_cache, v_cache,
                tokens, slots, positions, *, use_pallas=True):
    """One decode-only iteration over D lanes (the baseline decode batch).

    tokens/slots/positions: [D] int32. Inactive lanes point at the scratch
    slot with position 0. Returns (logits [D, vocab], k_cache, v_cache).
    """
    p = _unpack(cfg, params)
    d = tokens.shape[0]
    x = p["embed"][tokens]                                          # [D, H]

    def kv_update(l, k_new, v_new, kc, vc):
        kc = kc.at[l, slots, positions].set(k_new)
        vc = vc.at[l, slots, positions].set(v_new)
        return kc, vc

    def attention(l, q, kc, vc):
        krows = kc[l][slots].transpose(0, 2, 1, 3)                  # [D, nh, T, hd]
        vrows = vc[l][slots].transpose(0, 2, 1, 3)
        qd = q[:, None].transpose(0, 2, 1, 3)                       # [D, nh, 1, hd]
        thr = positions[:, None]                                    # [D, 1]
        fn = lambda qq, kk, vv, tt: _attn(qq, kk, vv, tt, use_pallas)
        out = jax.vmap(fn)(qd, krows, vrows, thr)                   # [D, nh, 1, hd]
        return out[:, :, 0].transpose(0, 1, 2).reshape(d, cfg.n_heads, cfg.head_dim)

    run = _run_body(cfg, p, x, positions, kv_update, attention, use_pallas)
    x, k_cache, v_cache = run(k_cache, v_cache)
    logits = x @ p["embed"].T                                       # [D, vocab]
    return logits, k_cache, v_cache


def hybrid_step(cfg: TinyConfig, params, k_cache, v_cache,
                p_tokens, p_slot, p_start, p_len,
                d_tokens, d_slots, d_positions, *, use_pallas=True):
    """One decode-maximal iteration (§4.3): ONE prefill chunk + D decode
    lanes. Linear operators run fused over the concatenated ``[C+D, H]``
    matrix (single Pallas GEMM — the piggybacking mechanism); the two
    attention computations run separately, exactly as the paper prescribes.

    Returns (p_logits [vocab], d_logits [D, vocab], k_cache, v_cache).
    """
    p = _unpack(cfg, params)
    c = p_tokens.shape[0]
    d = d_tokens.shape[0]
    p_positions = p_start + jnp.arange(c, dtype=jnp.int32)
    positions = jnp.concatenate([p_positions, d_positions])         # [C+D]
    x = p["embed"][jnp.concatenate([p_tokens, d_tokens])]           # [C+D, H]

    def kv_update(l, k_new, v_new, kc, vc):
        kp, kd = k_new[:c], k_new[c:]
        vp, vd = v_new[:c], v_new[c:]
        kc = jax.lax.dynamic_update_slice(kc, kp[None, None], (l, p_slot, p_start, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vp[None, None], (l, p_slot, p_start, 0, 0))
        kc = kc.at[l, d_slots, d_positions].set(kd)
        vc = vc.at[l, d_slots, d_positions].set(vd)
        return kc, vc

    def attention(l, q, kc, vc):
        # prefill-chunk attention (threshold mask across chunk boundaries)
        qp = q[:c].transpose(1, 0, 2)                               # [nh, C, hd]
        krow = jax.lax.dynamic_index_in_dim(kc[l], p_slot, 0, keepdims=False)
        vrow = jax.lax.dynamic_index_in_dim(vc[l], p_slot, 0, keepdims=False)
        outp = _attn(qp, krow.transpose(1, 0, 2), vrow.transpose(1, 0, 2),
                     p_positions, use_pallas).transpose(1, 0, 2)    # [C, nh, hd]
        # decode attention, batched over lanes
        krows = kc[l][d_slots].transpose(0, 2, 1, 3)                # [D, nh, T, hd]
        vrows = vc[l][d_slots].transpose(0, 2, 1, 3)
        qd = q[c:][:, None].transpose(0, 2, 1, 3)                   # [D, nh, 1, hd]
        fn = lambda qq, kk, vv, tt: _attn(qq, kk, vv, tt, use_pallas)
        outd = jax.vmap(fn)(qd, krows, vrows, d_positions[:, None])[:, :, 0]
        return jnp.concatenate([outp, outd], axis=0)                # [C+D, nh, hd]

    run = _run_body(cfg, p, x, positions, kv_update, attention, use_pallas)
    x, k_cache, v_cache = run(k_cache, v_cache)
    last = jax.lax.dynamic_index_in_dim(x, p_len - 1, axis=0, keepdims=False)
    p_logits = last @ p["embed"].T
    d_logits = x[c:] @ p["embed"].T
    return p_logits, d_logits, k_cache, v_cache


def full_prefill_reference(cfg: TinyConfig, params, tokens, *, use_pallas=False):
    """Un-chunked prefill of a whole prompt — the §4.2 mathematical-
    equivalence oracle for chunked prefills (used only by tests)."""
    import numpy as np

    k_cache = jnp.zeros((cfg.n_layers, cfg.kv_slots, cfg.max_len,
                         cfg.n_heads, cfg.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    logits, k_cache, v_cache = prefill_chunk_step(
        cfg, params, k_cache, v_cache,
        jnp.asarray(tokens, jnp.int32),
        jnp.int32(0), jnp.int32(0), jnp.int32(len(np.asarray(tokens))),
        use_pallas=use_pallas)
    return logits, k_cache, v_cache
