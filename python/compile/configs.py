"""Model configuration shared by the L2 JAX model, the AOT lowering and tests.

The repo serves a *tiny* LLaMA-style decoder end-to-end through PJRT on CPU
(the large-model experiments of the paper run through the calibrated cost
model on the rust side — see DESIGN.md §3). The tiny model is deliberately
small so that the full three-layer stack (Pallas kernel -> JAX model -> HLO
artifact -> rust PJRT runtime) stays fast enough to exercise hundreds of
serving iterations in the integration tests.
"""

from dataclasses import dataclass, field
from typing import List

import numpy as np


@dataclass(frozen=True)
class TinyConfig:
    """Architecture of the demo model served by the rust coordinator."""

    vocab: int = 256          # byte-level tokenizer on the rust side
    hidden: int = 128         # H
    n_heads: int = 4
    n_layers: int = 2
    ffn_hidden: int = 512     # H2 (paper-style two-matmul FFN, Table 1)
    max_len: int = 256        # maximum sequence length (P + D per request)
    kv_slots: int = 8         # KV-cache rows; the last row is scratch
    # Shape buckets lowered ahead-of-time. The scheduler only ever submits
    # these shapes; shorter chunks are padded and masked.
    chunk_sizes: tuple = (16, 32)
    decode_slots: int = 4     # decode lanes in the decode/hybrid steps

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.n_heads == 0
        return self.hidden // self.n_heads

    @property
    def scratch_slot(self) -> int:
        """KV row used by padded (inactive) decode lanes."""
        return self.kv_slots - 1

    @property
    def usable_slots(self) -> int:
        return self.kv_slots - 1


# Flat, ordered parameter list. The AOT manifest records this order and the
# rust runtime feeds weights positionally, so order is load-bearing.
def param_names(cfg: TinyConfig) -> List[str]:
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.ln1",
            f"l{l}.wqkv",
            f"l{l}.wo",
            f"l{l}.ln2",
            f"l{l}.w1",
            f"l{l}.w2",
        ]
    names.append("lnf")
    return names


def param_shapes(cfg: TinyConfig):
    h, h2 = cfg.hidden, cfg.ffn_hidden
    shapes = {"embed": (cfg.vocab, h), "lnf": (h,)}
    for l in range(cfg.n_layers):
        shapes[f"l{l}.ln1"] = (h,)
        shapes[f"l{l}.wqkv"] = (h, 3 * h)
        shapes[f"l{l}.wo"] = (h, h)
        shapes[f"l{l}.ln2"] = (h,)
        shapes[f"l{l}.w1"] = (h, h2)
        shapes[f"l{l}.w2"] = (h2, h)
    return shapes


def init_params(cfg: TinyConfig, seed: int = 0) -> List[np.ndarray]:
    """Deterministic synthetic weights (the paper's techniques are
    weight-agnostic; we only need a stable, non-degenerate model)."""
    rng = np.random.default_rng(seed)
    out = []
    for name in param_names(cfg):
        shape = param_shapes(cfg)[name]
        if name.endswith((".ln1", ".ln2")) or name == "lnf":
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0]
            arr = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
        out.append(arr)
    return out


def kv_shape(cfg: TinyConfig):
    """[layers, slots, max_len, n_heads, head_dim] — one row per request."""
    return (cfg.n_layers, cfg.kv_slots, cfg.max_len, cfg.n_heads, cfg.head_dim)
