"""Pallas fused linear kernel (L1) — the decode-maximal GEMM.

Decode-maximal batching (§4.3.1) fuses every linear operator (preproj,
postproj, ffn_ln1, ffn_ln2) over the *concatenated* ``[chunk + decodes]``
token matrix, so the weight tile streamed from HBM for the compute-saturating
prefill chunk is reused for the piggybacked decode rows — the mechanism that
makes decodes an order of magnitude cheaper (Table 2).

TPU adaptation: the grid tiles ``(token_tile, out_tile)`` map to MXU-sized
systolic tiles; each grid step holds one ``x`` row-tile and one ``w``
column-tile in VMEM and contracts the full ``H_in`` dimension (H_in is small
enough to fit in VMEM for the served model; the scheduler's tile alignment
keeps the token dimension a multiple of the tile, mirroring the paper's
Fig. 7 tile-quantization rule).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def fused_linear(x, w, *, block_t: int = 16, block_o: int = 128, interpret: bool = True):
    """Tiled ``x @ w`` over the fused token matrix.

    x: [T, H_in] — prefill-chunk rows followed by decode rows.
    w: [H_in, H_out].
    Tile sizes must divide the respective dimensions; the AOT step picks
    divisors of the shape buckets it lowers.
    """
    t, h_in = x.shape
    h_out = w.shape[1]
    bt = min(block_t, t)
    bo = min(block_o, h_out)
    if t % bt != 0 or h_out % bo != 0:
        raise ValueError(f"tiles ({bt},{bo}) must divide shape ({t},{h_out})")
    return pl.pallas_call(
        _mm_kernel,
        grid=(t // bt, h_out // bo),
        in_specs=[
            pl.BlockSpec((bt, h_in), lambda i, j: (i, 0)),
            pl.BlockSpec((h_in, bo), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, h_out), jnp.float32),
        interpret=interpret,
    )(x, w)
