"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is checked against the matching function here by `python/tests/test_kernels.py`
(hypothesis sweeps over shapes) before anything is lowered to HLO.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def chunked_attention_ref(q, k, v, thresholds):
    """Attention of a prefill chunk against the full KV row.

    Args:
      q:  [n_heads, C, head_dim]   queries of the chunk.
      k:  [n_heads, T, head_dim]   full cached keys (T = max_len).
      v:  [n_heads, T, head_dim]   full cached values.
      thresholds: [C] int32 — query i may attend keys at positions
        j <= thresholds[i]. For a chunk starting at `start`, thresholds[i] =
        start + i (the paper's Fig. 6 mask: each query peeks at every token
        preceding it, across chunk boundaries, never ahead).

    Returns [n_heads, C, head_dim].
    """
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("hcd,htd->hct", q, k) * scale        # [h, C, T]
    key_pos = jnp.arange(k.shape[1])[None, None, :]          # [1, 1, T]
    mask = key_pos <= thresholds[None, :, None]              # [1, C, T]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hct,htd->hcd", probs, v)


def fused_linear_ref(x, w, b=None):
    """Plain affine map over a (fused prefill-chunk + decode) token matrix.

    x: [T, H_in], w: [H_in, H_out], b: [H_out] or None -> [T, H_out].
    """
    y = x @ w
    if b is not None:
        y = y + b
    return y
