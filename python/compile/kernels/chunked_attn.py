"""Pallas chunked-prefill attention kernel (L1).

The paper's chunked-prefills (§4.2) require the attention of a prefill chunk
to cover (a) the KV of every *previous* chunk of the same request and (b) a
causal prefix within the current chunk. Both are expressed with one
per-query threshold vector: query i attends keys at positions
``j <= thresholds[i]``.

TPU adaptation (DESIGN.md §4): the kernel is written flash-style — the key
dimension is streamed through VMEM in ``block_k`` tiles with a running
(online-softmax) accumulator, which is the BlockSpec equivalent of the
threadblock HBM->shared-memory schedule the paper's xformers kernel uses on
GPU. ``interpret=True`` keeps the numerics exact on CPU-PJRT; on a real TPU
the same BlockSpec drives the Mosaic lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(thr_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """Grid: one step per head. Streams K/V in `block_k` tiles.

    q_ref: [C, d]; k_ref/v_ref: [T, d]; thr_ref: [C]; o_ref: [C, d].
    """
    q = q_ref[...]                                   # [C, d] in VMEM
    c, d = q.shape
    t = k_ref.shape[0]
    scale = d ** -0.5
    thr = thr_ref[...]                               # [C]

    n_blocks = t // block_k

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k_tile = k_ref[pl.dslice(i * block_k, block_k), :]       # [bk, d]
        v_tile = v_ref[pl.dslice(i * block_k, block_k), :]       # [bk, d]
        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32) * scale
        key_pos = i * block_k + jax.lax.broadcasted_iota(jnp.int32, (c, block_k), 1)
        s = jnp.where(key_pos <= thr[:, None], s, NEG_INF)
        # online softmax update
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))              # [C]
        p = jnp.exp(s - m_cur[:, None])                          # [C, bk]
        alpha = jnp.exp(m_prev - m_cur)                          # [C]
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        return acc, m_cur, l_cur

    init = (
        jnp.zeros((c, d), jnp.float32),
        jnp.full((c,), NEG_INF, jnp.float32),
        jnp.zeros((c,), jnp.float32),
    )
    acc, _, l = jax.lax.fori_loop(0, n_blocks, body, init)
    o_ref[...] = acc / l[:, None]


def chunked_attention(q, k, v, thresholds, *, block_k: int = 64, interpret: bool = True):
    """Pallas chunked-prefill attention.

    Args:
      q: [n_heads, C, head_dim] chunk queries.
      k, v: [n_heads, T, head_dim] full KV row (T = max_len, multiple of
        block_k; past-the-threshold entries are masked, so stale cache
        contents are never observable).
      thresholds: [C] int32, query i attends keys j <= thresholds[i].

    Returns: [n_heads, C, head_dim] float32.
    """
    n_heads, c, d = q.shape
    t = k.shape[1]
    if t % block_k != 0:
        raise ValueError(f"T={t} must be a multiple of block_k={block_k}")
    kernel = functools.partial(_attn_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((c,), lambda h: (0,)),               # thresholds
            pl.BlockSpec((None, c, d), lambda h: (h, 0, 0)),  # q, one head per step
            pl.BlockSpec((None, t, d), lambda h: (h, 0, 0)),  # k
            pl.BlockSpec((None, t, d), lambda h: (h, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, c, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, c, d), jnp.float32),
        interpret=interpret,
    )(thresholds, q, k, v)
