"""AOT lowering: JAX step functions -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the rust runtime then loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and executes
them on the PJRT CPU client. Python is never on the request path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids.

Artifacts emitted (shape buckets the scheduler is allowed to submit):
  prefill_c{C}           one chunked-prefill iteration, chunk size C
  decode_d{D}            one decode-only iteration over D lanes
  hybrid_c{C}_d{D}       one decode-maximal iteration (1 chunk + D lanes)
plus ``weights.npz`` (positional parameter order per configs.param_names)
and ``manifest.txt`` describing every artifact for the rust loader.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import TinyConfig, init_params, kv_shape, param_names, param_shapes
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _specs(cfg: TinyConfig):
    f32, i32 = jnp.float32, jnp.int32
    s = lambda shape, ty=f32: jax.ShapeDtypeStruct(shape, ty)
    params = [s(param_shapes(cfg)[n]) for n in param_names(cfg)]
    kv = s(kv_shape(cfg))
    return params, kv, s, i32


def lower_prefill(cfg: TinyConfig, chunk: int) -> str:
    params, kv, s, i32 = _specs(cfg)

    def fn(*args):
        p = list(args[: len(params)])
        k, v, tokens, slot, start, clen = args[len(params):]
        return M.prefill_chunk_step(cfg, p, k, v, tokens, slot, start, clen)

    lowered = jax.jit(fn).lower(
        *params, kv, kv, s((chunk,), i32), s((), i32), s((), i32), s((), i32)
    )
    return to_hlo_text(lowered)


def lower_decode(cfg: TinyConfig, d: int) -> str:
    params, kv, s, i32 = _specs(cfg)

    def fn(*args):
        p = list(args[: len(params)])
        k, v, tokens, slots, positions = args[len(params):]
        return M.decode_step(cfg, p, k, v, tokens, slots, positions)

    lowered = jax.jit(fn).lower(
        *params, kv, kv, s((d,), i32), s((d,), i32), s((d,), i32)
    )
    return to_hlo_text(lowered)


def lower_hybrid(cfg: TinyConfig, chunk: int, d: int) -> str:
    params, kv, s, i32 = _specs(cfg)

    def fn(*args):
        p = list(args[: len(params)])
        (k, v, p_tokens, p_slot, p_start, p_len,
         d_tokens, d_slots, d_positions) = args[len(params):]
        return M.hybrid_step(cfg, p, k, v, p_tokens, p_slot, p_start, p_len,
                             d_tokens, d_slots, d_positions)

    lowered = jax.jit(fn).lower(
        *params, kv, kv,
        s((chunk,), i32), s((), i32), s((), i32), s((), i32),
        s((d,), i32), s((d,), i32), s((d,), i32),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = TinyConfig()
    os.makedirs(args.out, exist_ok=True)

    # weights (positional order is load-bearing; manifest records it)
    params = init_params(cfg, seed=args.seed)
    np.savez(os.path.join(args.out, "weights.npz"),
             **{n: p for n, p in zip(param_names(cfg), params)})

    manifest = [
        "format 1",
        f"model tiny vocab={cfg.vocab} hidden={cfg.hidden} heads={cfg.n_heads} "
        f"layers={cfg.n_layers} ffn={cfg.ffn_hidden} max_len={cfg.max_len} "
        f"kv_slots={cfg.kv_slots} decode_slots={cfg.decode_slots}",
        "weights weights.npz " + " ".join(param_names(cfg)),
    ]

    def emit(name: str, text: str, line: str):
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(line)
        print(f"  {name}: {len(text)} chars")

    for c in cfg.chunk_sizes:
        emit(f"prefill_c{c}", lower_prefill(cfg, c),
             f"artifact name=prefill_c{c} kind=prefill chunk={c} file=prefill_c{c}.hlo.txt")
    d = cfg.decode_slots
    emit(f"decode_d{d}", lower_decode(cfg, d),
         f"artifact name=decode_d{d} kind=decode dslots={d} file=decode_d{d}.hlo.txt")
    for c in cfg.chunk_sizes:
        emit(f"hybrid_c{c}_d{d}", lower_hybrid(cfg, c, d),
             f"artifact name=hybrid_c{c}_d{d} kind=hybrid chunk={c} dslots={d} "
             f"file=hybrid_c{c}_d{d}.hlo.txt")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest) - 3} artifacts + weights + manifest to {args.out}")


if __name__ == "__main__":
    main()
